"""PipeCheck static pass (tools/pipecheck.py, repro.analysis): the real
tree is clean, every rule (R1-R6) fires on its fixture, and the CLI
emits clickable ``file:line: RULE`` lines with a failing exit status.
"""
import subprocess
import sys
from pathlib import Path

from repro.analysis import run_checks, scan_tree

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).parent / "pipecheck_fixtures"


def _fx(name: str) -> str:
    return (FIXTURES / name).read_text()


def _rules(findings):
    return {f.rule for f in findings}


# --------------------------------------------------------------------------- #
# the real tree
# --------------------------------------------------------------------------- #
def test_real_tree_is_clean():
    findings = scan_tree(REPO)
    assert findings == [], "\n".join(f.render() for f in findings)


# --------------------------------------------------------------------------- #
# R1 — exhaustive token dispatch
# --------------------------------------------------------------------------- #
def test_r1_fires_on_silent_token_drop():
    findings = run_checks(
        {"src/repro/runtime/badloop.py": _fx("r1_silent_drop.py")})
    assert _rules(findings) == {"R1"}
    (f,) = findings
    assert f.path == "src/repro/runtime/badloop.py" and f.line > 0
    assert "WARMUP" in f.message and "RECONFIG" in f.message  # the dropped kinds


def test_r1_accepts_explicit_defaults_and_full_coverage():
    findings = run_checks(
        {"src/repro/runtime/okloop.py": _fx("r1_explicit_default.py")})
    assert findings == [], [f.render() for f in findings]


def test_r1_applies_everywhere_not_just_runtime():
    findings = run_checks({"src/repro/core/x.py": _fx("r1_silent_drop.py")})
    assert _rules(findings) == {"R1"}


# --------------------------------------------------------------------------- #
# R2 — codec registry
# --------------------------------------------------------------------------- #
def test_r2_fires_on_registry_violations():
    findings = run_checks({
        "src/repro/core/codecs.py": _fx("r2_codec_registry.py"),
        "src/repro/kernels/ref.py": _fx("r2_ref_stub.py"),
    })
    msgs = [f.message for f in findings]
    assert all(f.rule == "R2" for f in findings)
    assert any("collides" in m for m in msgs)                 # code 3 reused
    assert any("not recorded in" in m for m in msgs)          # code 9 unpinned
    assert any("inherits `encode`" in m for m in msgs)        # identity model
    assert any("gzip_pack" in m and "oracle" in m for m in msgs)


def test_r2_fires_on_renamed_wire_code():
    src = _fx("r2_codec_registry.py").replace(
        'name = "int8"', 'name = "i8"')
    findings = run_checks({
        "src/repro/core/codecs.py": src,
        "src/repro/kernels/ref.py": _fx("r2_ref_stub.py"),
    })
    assert any("pinned to codec 'int8'" in f.message for f in findings)


def test_r2_real_registry_matches_manifest():
    # the actual codecs.py against the actual ref.py, in isolation
    findings = run_checks({
        "src/repro/core/codecs.py":
            (REPO / "src/repro/core/codecs.py").read_text(),
        "src/repro/kernels/ref.py":
            (REPO / "src/repro/kernels/ref.py").read_text(),
    }, rules=("R2",))
    assert findings == [], [f.render() for f in findings]


# --------------------------------------------------------------------------- #
# R3 — Channel surface + record() accounting
# --------------------------------------------------------------------------- #
def test_r3_fires_on_partial_channel_and_bare_record():
    findings = run_checks(
        {"src/repro/runtime/halfchan.py": _fx("r3_half_channel.py")})
    assert all(f.rule == "R3" for f in findings)
    missing = {m for f in findings for m in ("recv", "reap", "set_codec")
               if f"`{m}`" in f.message}
    assert missing == {"recv", "reap", "set_codec"}
    assert any("raw_bytes" in f.message for f in findings)


def test_r3_record_lint_is_runtime_scoped():
    # the same source outside runtime/ carries no record() obligations
    findings = run_checks(
        {"src/repro/core/halfchan.py": _fx("r3_half_channel.py")})
    assert not any("raw_bytes" in f.message for f in findings)


# --------------------------------------------------------------------------- #
# R4 — pickle escape hatches
# --------------------------------------------------------------------------- #
def test_r4_fires_on_hot_path_pickle():
    findings = run_checks(
        {"src/repro/runtime/fastpath.py": _fx("r4_pickle_hot_path.py")})
    assert _rules(findings) == {"R4"}
    assert len(findings) == 2                 # module fn + wrong-file class
    assert any("frame_fast" in f.message for f in findings)


def test_r4_allows_the_declared_hatches_and_non_runtime_code():
    # same source, non-runtime path: out of R4 scope entirely
    assert run_checks(
        {"src/repro/tools_helper.py": _fx("r4_pickle_hot_path.py")}) == []
    # the real transport.py keeps its declared hatches without findings
    findings = run_checks(
        {"src/repro/runtime/transport.py":
            (REPO / "src/repro/runtime/transport.py").read_text()},
        rules=("R4",))
    assert findings == [], [f.render() for f in findings]


# --------------------------------------------------------------------------- #
# R5 — struct layout version
# --------------------------------------------------------------------------- #
def test_r5_fires_on_layout_drift():
    findings = run_checks(
        {"src/repro/runtime/transport.py": _fx("r5_layout_drift.py")})
    assert _rules(findings) == {"R5"}
    (f,) = findings
    assert "_FHDR" in f.message and "bump" in f.message.lower()


def test_r5_fires_on_missing_version():
    src = _fx("r5_layout_drift.py").replace("WIRE_LAYOUT_VERSION = 1", "")
    findings = run_checks({"src/repro/runtime/transport.py": src})
    assert any("no WIRE_LAYOUT_VERSION" in f.message for f in findings)


def test_r5_fires_on_unknown_version():
    src = _fx("r5_layout_drift.py").replace(
        "WIRE_LAYOUT_VERSION = 1", "WIRE_LAYOUT_VERSION = 99")
    findings = run_checks({"src/repro/runtime/transport.py": src})
    assert any("no entry" in f.message for f in findings)


# --------------------------------------------------------------------------- #
# R6 — timeout-guarded blocking channel ops
# --------------------------------------------------------------------------- #
def test_r6_fires_on_unguarded_blocking_ops():
    findings = run_checks(
        {"src/repro/runtime/r6loop.py": _fx("r6_bare_recv.py")})
    assert _rules(findings) == {"R6"}
    msgs = [f.message for f in findings]
    assert any("bare blocking recv()" in m for m in msgs)
    assert any("sendmsg" in m for m in msgs)
    # the poll-then-recv shape is compliant and must not fire
    assert not any("drain_guarded" in m for m in msgs)


def test_r6_is_runtime_scoped():
    assert run_checks(
        {"src/repro/core/r6loop.py": _fx("r6_bare_recv.py")}) == []


def test_r6_real_runtime_is_guarded():
    # every blocking channel op in the live runtime carries a timeout or
    # a poll() liveness loop (the edge.py hole this rule was written for)
    findings = run_checks(
        {f"src/repro/runtime/{p.name}": p.read_text()
         for p in (REPO / "src/repro/runtime").glob("*.py")},
        rules=("R6",))
    assert findings == [], [f.render() for f in findings]


# --------------------------------------------------------------------------- #
# the CLI
# --------------------------------------------------------------------------- #
def test_cli_clean_tree_exits_zero():
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "pipecheck.py")],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "clean" in out.stdout


def test_cli_fix_report_emits_clickable_lines(tmp_path):
    bad = tmp_path / "src" / "repro" / "runtime"
    bad.mkdir(parents=True)
    (bad / "badloop.py").write_text(_fx("r1_silent_drop.py"))
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "pipecheck.py"),
         "--root", str(tmp_path), "--fix-report"],
        capture_output=True, text=True)
    assert out.returncode == 1
    line = out.stdout.strip().splitlines()[0]
    # file:line: RULE message — clickable in editors and CI logs
    path, lineno, rest = line.split(":", 2)
    assert path == "src/repro/runtime/badloop.py"
    assert lineno.isdigit()
    assert rest.strip().startswith("R1")


def test_cli_rejects_unknown_rules():
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "pipecheck.py"),
         "--rules", "R9"],
        capture_output=True, text=True)
    assert out.returncode == 2
