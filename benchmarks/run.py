"""Benchmark harness — one function per paper table/figure, plus the
beyond-paper pod-scale sweep.  Prints ``name,us_per_call,derived`` CSV
(after the human-readable artifacts).

    PYTHONPATH=src python -m benchmarks.run [--only fig7] [--quick]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="skip the measured (wall-clock) benches")
    args = ap.parse_args()

    from . import codec_bench as C
    from . import energy_front as E
    from . import kway_runtime as K
    from . import paper_tables as P
    from . import replica_bench as R
    from . import serve_bench as SG
    from . import stream_bench as S
    from . import tpu_pod_pareto as T
    from . import transport_bench as TR

    benches = {
        "table1": P.table1_models,
        "fig2": P.fig2_blockwise,
        "fig3": P.fig3_pareto_pi_pi,
        "fig4": P.fig4_pareto_pi_gpu,
        "fig56": P.fig56_duress,
        "fig7": P.fig7_backends,
        "table23": P.table23_breakdown,
        "pod_pareto": T.pod_pareto,
        "kway_front": K.kway_front,
        "kway_adaptive": K.kway_adaptive,
        "energy_front": E.energy_front,
        "pareto_bench": E.pareto_bench,
        "transport_overhead": TR.transport_overhead,
        "stream_session": S.stream_throughput,
        "codec_overhead": C.codec_overhead,
        "replica_fanout": R.run,
        "serve_gateway": SG.serve_throughput,
    }
    measured = {"fig2", "fig7", "kway_front", "kway_adaptive",
                "transport_overhead", "stream_session", "codec_overhead",
                "replica_fanout", "serve_gateway"}
    rows: list[str] = []
    for name, fn in benches.items():
        if args.only and args.only not in name:
            continue
        if args.quick and name in measured:
            continue
        try:
            rows.extend(fn())
        except Exception as e:  # surface but keep the harness going
            print(f"[bench {name} FAILED] {type(e).__name__}: {e}",
                  file=sys.stderr)
            rows.append(f"{name}/FAILED,0.0,{type(e).__name__}")

    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
