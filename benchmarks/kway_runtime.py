"""Beyond-paper: k-stage executable pipeline vs. the analytic model.

Two artifacts on the 3-stage pi→pi→gpu chain:

  * ``kway_front``    — predicted (``dp_front_kway``, host-calibrated via
    block-wise wall-clock profiling) vs. *measured* (``EdgePipeline``)
    latency fronts, under healthy links and under the fully-degraded WAN
    (the ramp's two endpoints).  Reports pairwise ordering agreement —
    the property that makes the analytic front trustworthy for placement.
  * ``kway_adaptive`` — the closed loop under the degrading ``LinkTrace``
    (observed wire times → estimators → re-solve → live migration),
    reporting the migration trail and the latency it saved versus
    pinning the initial cuts.
"""
from __future__ import annotations

import itertools

import jax
import numpy as np

from repro.core import CostTable, dp_front_kway, pareto_front, scenarios
from repro.core.profiler import profile_wallclock
from repro.models.cnn import zoo
from repro.runtime.adaptive import AdaptiveRuntime
from repro.runtime.edge import EdgePipeline

BATCH = 2
HW = 32


def _setup():
    m = zoo.get("mobilenetv2")
    params = m.init(jax.random.PRNGKey(0))
    graph = m.block_graph(input_hw=HW)
    x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, HW, HW, 3))
    return m, params, graph, x


def _host_costs(m, params, x, device_names) -> CostTable:
    """Calibrate the analytic side to THIS host: wall-clock profile every
    block once, then bill every scenario device at host speed (the
    executable workers are all host threads)."""
    names, fns = m.block_fns(params)
    table = profile_wallclock(device_names[0], fns, names,
                              make_input=lambda _: x, repeats=3)
    for dev in device_names[1:]:
        for blk in names:
            table.set(dev, blk, table.get(device_names[0], blk))
    return table


def _pairwise_agreement(pred: list[float], meas: list[float],
                        margin: float = 0.10) -> tuple[float | None, int]:
    """(agreement, n_decisive_pairs): fraction of *decisive* point pairs
    (predicted latencies differing by more than ``margin``) whose
    predicted ordering matches the measured ordering.  Near-ties carry
    no placement information, so they are excluded rather than counted
    as coin flips; with no decisive pair at all the agreement is None
    (unknown), never a vacuous 1.0."""
    pairs = [(i, j) for i, j in itertools.combinations(range(len(pred)), 2)
             if abs(pred[i] - pred[j]) / max(pred[i], pred[j]) > margin]
    if not pairs:
        return None, 0
    ok = sum((pred[i] < pred[j]) == (meas[i] < meas[j]) for i, j in pairs)
    return ok / len(pairs), len(pairs)


def kway_front() -> list[str]:
    print("\n== k-way runtime: predicted vs measured front (pi->pi->gpu) ==")
    m, params, graph, x = _setup()
    ramp = scenarios.get("pi_pi_gpu_wan_ramp")
    costs = _host_costs(m, params, x, [d.name for d in ramp.devices])
    rows: list[str] = []
    for cond, t in (("healthy", 0.0), ("degraded", 1e9)):
        scen = ramp.at(t)
        front = dp_front_kway(graph, scen.devices, scen.links, batch=BATCH,
                              costs=costs, include_io=False)
        picks = front[:: max(len(front) // 4, 1)][:4]
        pred_lat, meas_lat, pred_thr, meas_thr = [], [], [], []
        for pt in picks:
            pipe = EdgePipeline(m, params, pt.partition, scen)
            r = pipe.measure(lambda: x, n_batches=6)
            pred_lat.append(pt.latency_s)
            meas_lat.append(r.latency_s)
            pred_thr.append(pt.throughput)
            meas_thr.append(r.throughput)
            print(f"  {cond:9s} cuts={pt.partition}  "
                  f"lat {pt.latency_s*1e3:8.1f} -> {r.latency_s*1e3:8.1f} ms"
                  f"   thr {pt.throughput:7.1f} -> {r.throughput:7.1f}/s")
        # On healthy links lone-batch latency is partition-invariant
        # (the paper's finding) — the throughput axis carries the
        # ordering information there; under duress the wire dominates
        # and latency becomes decisive too.
        for axis, pred, meas in (("lat", pred_lat, meas_lat),
                                 ("thr", pred_thr, meas_thr)):
            agree, n_pairs = _pairwise_agreement(pred, meas)
            label = ("n/a (no decisive pairs)" if agree is None
                     else f"{agree:.2f}")
            print(f"  {cond:9s} {axis} ordering agreement: {label} "
                  f"({n_pairs} decisive pairs over {len(picks)} points)")
            rows.append(
                f"kway_front/{cond}/{axis},0.0,"
                f"agreement={'nan' if agree is None else f'{agree:.2f}'};"
                f"pairs={n_pairs};points={len(picks)}")
    return rows


def kway_adaptive() -> list[str]:
    print("\n== k-way runtime: closed adaptive loop under WAN ramp ==")
    m, params, graph, x = _setup()
    base = scenarios.get("pi_pi_gpu")
    scen = scenarios.wan_ramp(base, hop=0, t_start=0.3, t_end=1.5,
                              jitter=0.0)
    n_batches = 20

    rt = AdaptiveRuntime(m, params, scen, graph=graph, batch=BATCH,
                         policy="throughput", check_every=2,
                         migration_cost_s=0.05, alpha=0.6)
    start = rt.pipe.cuts
    recs = rt.run(lambda: x, n_batches=n_batches)
    adaptive_tail = float(np.mean([r.latency_s for r in recs[-4:]]))

    # baseline: cuts pinned at the lab-condition choice, measured on the
    # fully-degraded link (a few lone batches — each is seconds-long)
    pinned = EdgePipeline(m, params, start, scen.at(1e9))
    pinned.warmup(x)
    pinned_tail = float(np.mean([pinned.run_one(x)[1] for _ in range(3)]))

    trail = " -> ".join(map(str, rt.cut_history))
    print(f"  cuts {trail}  ({len(rt.pipe.migrations)} migrations)")
    print(f"  steady-state latency after degrade: adaptive "
          f"{adaptive_tail*1e3:7.1f} ms vs pinned {pinned_tail*1e3:7.1f} ms "
          f"({pinned_tail/max(adaptive_tail, 1e-9):.1f}x)")
    rows = [f"kway_adaptive/migrations,0.0,n={len(rt.pipe.migrations)}",
            f"kway_adaptive/tail_latency,{adaptive_tail*1e6:.0f},"
            f"pinned_x={pinned_tail/max(adaptive_tail, 1e-9):.1f}"]
    return rows
