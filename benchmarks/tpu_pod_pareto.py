"""Beyond-paper: ParetoPipe at pod scale — frontier of pipeline cuts for
the 10 assigned LM archs on the 2-pod production mesh (DCN links), plus
the duress analogue (congested DCN), from the same analytic block costs
the dry-run roofline uses."""
from __future__ import annotations

import time

import repro.configs as configs
from repro.core import (best_latency, best_throughput, dp_front_kway,
                        pareto_front)
from repro.core import scenarios
from repro.models.blocks_adapter import arch_block_graph

from .common import emit


def pod_pareto(seq: int = 4096, batch: int = 256, train: bool = True,
               n_pods: int = 2) -> list[str]:
    rows = []
    base = scenarios.pods(n_pods)
    cong = scenarios.pods_congested(n_pods)
    print(f"\n== Pod-level ParetoPipe (seq={seq}, {n_pods} pods, "
          f"{'train' if train else 'serve'}) ==")
    print(f"{'arch':24s} {'cuts(DCN)':>12s} {'bound ms':>9s} "
          f"{'cuts(congested)':>16s} {'bound ms':>9s} {'moved':>6s}")
    for name in configs.ARCH_NAMES:
        cfg = configs.get(name)
        g = arch_block_graph(cfg, seq, train=train)
        t0 = time.perf_counter()
        f1 = dp_front_kway(g, base.devices, base.links, batch=batch)
        f2 = dp_front_kway(g, cong.devices, cong.links, batch=batch)
        dt = time.perf_counter() - t0
        b1, b2 = best_throughput(f1), best_throughput(f2)
        moved = b1.partition != b2.partition
        print(f"{name:24s} {str(b1.partition):>12s} "
              f"{batch/b1.throughput*1e3:>9.1f} {str(b2.partition):>16s} "
              f"{batch/b2.throughput*1e3:>9.1f} {str(moved):>6s}")
        rows.append(f"pod_pareto/{name},{dt*1e6/2:.0f},"
                    f"cuts={b1.partition};cong_cuts={b2.partition};"
                    f"moved={moved}")
    print("(cuts are block indices: 0=embed, 1..L=layers, L+1=head)")
    return rows
