"""Beyond-paper: the 3-objective (latency, throughput, energy) study.

Two artifacts:

  * ``energy_front`` — the trade-off *surface* on the k-stage chains
    under the existing WAN-ramp traces: at the ramp's healthy and
    degraded endpoints, how the 3-D front widens past the 2-D one
    (splits that are latency/throughput-equivalent but joules-apart),
    which split each single-objective policy picks, and what the duress
    WAN's radio cost does to the energy-optimal cut.
  * ``pareto_bench`` — machine-readable solver trajectory: front sizes,
    hypervolume, and solve wall-time for the 2- and 3-objective DP on
    every model × scenario pair, written to ``BENCH_pareto.json`` so
    future PRs can diff perf instead of guessing.

    PYTHONPATH=src python -m benchmarks.energy_front [--smoke]

``--smoke`` runs a tiny synthetic graph only (< 30 s, the Makefile
``bench-smoke`` target) and still writes BENCH_pareto.json.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core import (Block, BlockGraph, best_energy, best_latency,
                        best_throughput, dp_front_kway, hypervolume,
                        pareto_front, scenarios, sweep_kway)

OBJ2 = ("latency", "throughput")
OBJ3 = ("latency", "throughput", "energy")
BATCH = 8
BENCH_JSON = Path("BENCH_pareto.json")


def tiny_graph(n: int = 8) -> BlockGraph:
    """Deterministic small chain for smoke runs and cross-validation:
    alternating fat/thin blocks so cuts genuinely trade bytes for flops."""
    blocks = tuple(
        Block(f"b{i}",
              flops=(3e8 if i % 2 else 6e7) * (1 + i / n),
              weight_bytes=200_000 + 40_000 * i,
              out_bytes=400_000 if i % 3 else 40_000)
        for i in range(n))
    return BlockGraph("tiny", blocks, input_bytes=120_000, output_bytes=4_000)


def _refs(pts):
    """Reference vectors strictly worse than the cloud on every axis."""
    lat = max(p.latency_s for p in pts) * 1.1
    en = max(p.energy_j for p in pts) * 1.1
    thr = min(p.throughput for p in pts) * 0.9
    return (lat, thr), (lat, thr, en)


def _solve_stats(graph, scen, batch):
    """Time the DP at 2 and 3 objectives + exhaustive point cloud."""
    out = {}
    pts = sweep_kway(graph, scen.devices, scen.links, batch=batch)
    ref2, ref3 = _refs(pts)
    for tag, objs, ref in (("2obj", OBJ2, ref2), ("3obj", OBJ3, ref3)):
        t0 = time.perf_counter()
        front = dp_front_kway(graph, scen.devices, scen.links, batch=batch,
                              objectives=objs)
        dt = time.perf_counter() - t0
        out[tag] = {
            "front_size": len(front),
            "hypervolume": hypervolume(front, ref, objs),
            "solve_s": dt,
        }
    out["n_partitions"] = len(pts)
    return out, pts


def energy_front(models=("mobilenetv2", "resnet18")) -> list[str]:
    """The 3-objective trade-off on the battery chain (pi_only3) and the
    WAN-ramp chain (pi_pi_gpu), healthy vs. degraded.  The headline
    number is the *pick divergence*: how many joules the energy-aware
    pick saves over the throughput pick, and what that costs in
    throughput — the axis a 2-objective solver cannot see."""
    from repro.models.cnn import zoo
    rows: list[str] = []
    print("\n== 3-objective fronts: battery chain + WAN ramp ==")
    ramp = scenarios.get("pi_pi_gpu_wan_ramp")
    conds = [("pi_only3", "healthy", scenarios.get("pi_only3")),
             ("pi_only3", "duress", scenarios.get("pi_only3_duress")),
             ("wan_ramp", "healthy", ramp.at(0.0)),
             ("wan_ramp", "degraded", ramp.at(1e9))]
    for name in models:
        g = zoo.get(name).block_graph()
        for chain, cond, scen in conds:
            pts = sweep_kway(g, scen.devices, scen.links, batch=BATCH)
            f2 = pareto_front(pts, OBJ2)
            f3 = pareto_front(pts, OBJ3)
            bt, be = best_throughput(pts), best_energy(pts)
            j_saved = bt.energy_j - be.energy_j
            thr_cost = (1 - be.throughput / bt.throughput) * 100
            print(f"{name:12s} {chain:8s} {cond:8s} "
                  f"front 2D={len(f2):2d} 3D={len(f3):2d} | "
                  f"thr-pick {bt.partition} {bt.energy_j:6.2f} J | "
                  f"J-pick {be.partition} {be.energy_j:6.2f} J "
                  f"(saves {j_saved:5.2f} J, costs {thr_cost:4.1f}% thr)")
            rows.append(
                f"energy_front/{name}/{chain}/{cond},0.0,"
                f"front2={len(f2)};front3={len(f3)};"
                f"j_saved={j_saved:.2f};thr_cost_pct={thr_cost:.1f}")
    print("(equal-watt Pi chains: energy tracks bytes moved, so the J-pick "
          "hugs min-transfer cuts while the thr-pick balances stages; the "
          "GPU is the more J/FLOP-efficient device, so offloading saves "
          "both time and joules until the wire degrades)")
    return rows


def pareto_bench(smoke: bool = False, out_path: Path = BENCH_JSON) -> list[str]:
    """Solver perf + front trajectory → BENCH_pareto.json + CSV rows."""
    rows: list[str] = []
    results: dict = {"batch": BATCH, "entries": []}
    print("\n== pareto solver bench (2 vs 3 objectives) ==")
    cases: list[tuple[str, BlockGraph, object]] = [
        ("tiny/pi_only3", tiny_graph(), scenarios.get("pi_only3"))]
    if not smoke:
        from repro.models.cnn import zoo
        for name in ("mobilenetv2", "resnet18", "resnet50"):
            g = zoo.get(name).block_graph()
            for scen_name in ("pi_only3", "pi_pi_gpu", "pi_chain4"):
                cases.append((f"{name}/{scen_name}", g,
                              scenarios.get(scen_name)))
    for label, g, scen in cases:
        stats, _ = _solve_stats(g, scen.at(0.0), BATCH)
        results["entries"].append({"case": label, **stats})
        s2, s3 = stats["2obj"], stats["3obj"]
        print(f"{label:28s} parts={stats['n_partitions']:6d} "
              f"| 2obj front={s2['front_size']:3d} hv={s2['hypervolume']:9.3f}"
              f" {s2['solve_s']*1e3:7.1f} ms "
              f"| 3obj front={s3['front_size']:3d} hv={s3['hypervolume']:9.3f}"
              f" {s3['solve_s']*1e3:7.1f} ms")
        for tag in ("2obj", "3obj"):
            s = stats[tag]
            rows.append(f"pareto_bench/{label}/{tag},{s['solve_s']*1e6:.0f},"
                        f"front={s['front_size']};hv={s['hypervolume']:.3f}")
    out_path.write_text(json.dumps(results, indent=1))
    print(f"[pareto_bench] wrote {out_path}")
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph only; < 30 s; still writes "
                         "BENCH_pareto.json")
    args = ap.parse_args()
    rows = pareto_bench(smoke=args.smoke)
    if not args.smoke:
        rows += energy_front()
    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
