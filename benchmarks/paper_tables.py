"""Reproduction of every paper table/figure (one function each).

Model-driven sweeps use the calibrated testbed (core.scenarios); the
measured benches (fig2 wall-clock, fig7 backends) execute real
partitioned pipelines on this host.  Each function returns a list of CSV
rows for ``benchmarks.run`` and prints the human-readable artifact.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (CostTable, best_latency, best_throughput,
                        hypervolume, pareto_front, sweep_2way)
from repro.core import scenarios
from repro.core.profiler import coefficient_of_variation, profile_wallclock
from repro.models.cnn import zoo

from .common import ascii_pareto, emit, timed

CNNS = ("mobilenetv2", "resnet18", "inceptionv3", "resnet50", "alexnet",
        "vgg16")
BATCH = 8          # the paper's operating batch size


# --------------------------------------------------------------------------- #
def table1_models() -> list[str]:
    """Table I: params / blocks / size."""
    rows = []
    print("\n== Table I: models ==")
    print(f"{'model':14s} {'params':>12s} {'blocks':>7s} {'size MB':>8s}")
    for name in CNNS:
        m = zoo.get(name, num_classes=10)
        g = m.block_graph()
        n = m.param_count()
        mb = g.total_weight_bytes / 1e6
        print(f"{name:14s} {n:>12,} {len(m.blocks):>7d} {mb:>8.1f}")
        rows.append(f"table1/{name},0.0,params={n};blocks={len(m.blocks)};"
                    f"mb={mb:.1f}")
    return rows


def fig2_blockwise(measure: bool = True) -> list[str]:
    """Fig. 2: block-wise execution times are heterogeneous."""
    rows = []
    print("\n== Fig 2: block-wise profiling (host CPU, 32x32) ==")
    for name in ("mobilenetv2", "resnet18"):
        m = zoo.get(name)
        params = m.init(jax.random.PRNGKey(0))
        names, fns = m.block_fns(params)
        x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, 32, 32, 3))
        t0 = time.perf_counter()
        table = profile_wallclock("host", fns, names, lambda _: x, repeats=3)
        wall = time.perf_counter() - t0
        times = [table.get("host", n) for n in names]
        cv = coefficient_of_variation(times)
        peak = max(times)
        print(f"{name}: CV of block times = {cv:.2f} "
              f"(paper's finding: blocks are NOT equal); "
              f"max block {peak*1e3:.1f} ms")
        rows.append(f"fig2/{name},{wall/len(names)*1e6:.1f},cv={cv:.2f}")
    return rows


def _pareto_sweep(scen_name: str) -> list[str]:
    rows = []
    scen = scenarios.get(scen_name)
    print(f"\n== Pareto frontiers ({scen_name}) ==")
    for name in CNNS:
        g = zoo.get(name).block_graph()
        t0 = time.perf_counter()
        pts = sweep_2way(g, scen.devices, scen.links[0], batch=BATCH)
        dt = time.perf_counter() - t0
        front = pareto_front(pts)
        bt, bl = best_throughput(pts), best_latency(pts)
        hv = hypervolume(pts, ref_latency=max(p.latency_s for p in pts) * 1.1)
        print(f"{name:14s} front={len(front):2d}/{len(pts):2d} "
              f"best-thr P{bt.partition[0]:<2d} {bt.throughput:8.2f} img/s | "
              f"best-lat P{bl.partition[0]:<2d} {bl.latency_s*1e3:9.1f} ms")
        rows.append(f"pareto/{scen_name}/{name},{dt/len(pts)*1e6:.1f},"
                    f"front={len(front)};thr={bt.throughput:.2f};"
                    f"lat_ms={bl.latency_s*1e3:.1f};hv={hv:.3f}")
    # one visual
    g = zoo.get("mobilenetv2").block_graph()
    pts = sweep_2way(g, scen.devices, scen.links[0], batch=BATCH)
    print(ascii_pareto(pts, pareto_front(pts),
                       title=f"mobilenetv2 @ {scen_name}"))
    return rows


def fig3_pareto_pi_pi() -> list[str]:
    return _pareto_sweep("pi_to_pi")


def fig4_pareto_pi_gpu() -> list[str]:
    return _pareto_sweep("pi_to_gpu")


def fig56_duress() -> list[str]:
    """Figs 5/6: 200 ms RTT + 5 Mbit/s shifts the whole frontier."""
    rows = []
    print("\n== Figs 5/6: network duress (200ms, 5Mbit/s) ==")
    for scen_name in ("pi_to_pi", "pi_to_gpu"):
        base = scenarios.get(scen_name)
        dur = scenarios.duress(base)
        for name in CNNS:
            g = zoo.get(name).block_graph()
            p_base = sweep_2way(g, base.devices, base.links[0], batch=BATCH)
            p_dur = sweep_2way(g, dur.devices, dur.links[0], batch=BATCH)
            bt_b, bt_d = best_throughput(p_base), best_throughput(p_dur)
            shift = bt_b.throughput / max(bt_d.throughput, 1e-9)
            moved = bt_b.partition != bt_d.partition
            print(f"{scen_name:9s} {name:14s} thr {bt_b.throughput:8.2f} → "
                  f"{bt_d.throughput:6.3f} img/s ({shift:6.1f}x) "
                  f"opt split P{bt_b.partition[0]}→P{bt_d.partition[0]}"
                  f"{'  *moved*' if moved else ''}")
            rows.append(f"fig56/{scen_name}/{name},0.0,"
                        f"degrade_x={shift:.1f};moved={moved}")
    return rows


def fig7_backends() -> list[str]:
    """Fig. 7: RPC-like vs lightweight backend, measured on host."""
    from repro.core.devices import Link
    from repro.runtime.edge import EdgePipeline
    rows = []
    print("\n== Fig 7: communication backends (measured, host) ==")
    m = zoo.get("mobilenetv2")
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, 64, 64, 3))
    link = Link("lan", rtt_s=0.201e-3, bw_bytes_per_s=125e6)
    res = {}
    for backend in ("lightweight", "rpc"):
        pipe = EdgePipeline(m, params, p=3, link=link, backend=backend)
        r = pipe.measure(lambda: x, n_batches=8)
        res[backend] = r
        print(f"{backend:12s} latency {r.latency_s*1e3:7.1f} ms  "
              f"throughput {r.throughput:6.1f} img/s  "
              f"stage_exe {tuple(round(s*1e3,1) for s in r.stage_exe_s)} ms")
        rows.append(f"fig7/{backend},{r.latency_s*1e6:.0f},"
                    f"thr={r.throughput:.1f}")
    lat_gain = 1 - res["lightweight"].latency_s / res["rpc"].latency_s
    thr_gain = res["lightweight"].throughput / res["rpc"].throughput - 1
    print(f"lightweight vs rpc: latency −{lat_gain*100:.0f}%  "
          f"throughput +{thr_gain*100:.0f}%   (paper: −76% / +53%)")
    rows.append(f"fig7/gain,0.0,lat_red={lat_gain:.2f};thr_gain={thr_gain:.2f}")
    return rows


def table23_breakdown() -> list[str]:
    """Tables II/III: per-stage breakdown at notable Pareto points."""
    rows = []
    for scen_name, table in (("pi_to_pi", "II"), ("pi_to_gpu", "III")):
        scen = scenarios.get(scen_name)
        print(f"\n== Table {table}: breakdown ({scen_name}) ==")
        print(f"{'model(split)':22s} {'s1_exe':>8s} {'s2_exe':>8s} "
              f"{'net':>7s} {'thr':>8s}")
        for name in CNNS:
            g = zoo.get(name).block_graph()
            pts = sweep_2way(g, scen.devices, scen.links[0], batch=BATCH)
            front = pareto_front(pts)
            picks = {best_throughput(pts).partition,
                     best_latency(pts).partition}
            for m in front:
                if m.partition not in picks:
                    continue
                s1, s2 = m.stages
                print(f"{name}(P{m.partition[0]:<3d})".ljust(22)
                      + f" {s1.compute_s:8.3f} {s2.compute_s:8.3f}"
                      f" {m.net_s:7.3f} {m.throughput:8.2f}")
                rows.append(
                    f"table23/{scen_name}/{name}/P{m.partition[0]},0.0,"
                    f"s1={s1.compute_s:.3f};s2={s2.compute_s:.3f};"
                    f"net={m.net_s:.3f};thr={m.throughput:.2f}")
    return rows
