"""Shared benchmark utilities: CSV emission + ASCII Pareto plots."""
from __future__ import annotations

import sys
import time


def emit(name: str, us_per_call: float, derived: str = ""):
    """The harness contract: ``name,us_per_call,derived`` CSV rows."""
    print(f"{name},{us_per_call:.3f},{derived}")


def timed(fn, *args, repeats: int = 3):
    import jax
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats, out


def ascii_pareto(points, front, width: int = 60, height: int = 14,
                 title: str = "") -> str:
    """Latency (x, s) vs throughput (y) scatter with the front marked."""
    if not points:
        return "(no points)"
    lats = [p.latency_s for p in points]
    thrs = [p.throughput for p in points]
    lo_x, hi_x = min(lats), max(lats)
    lo_y, hi_y = min(thrs), max(thrs)
    dx = (hi_x - lo_x) or 1.0
    dy = (hi_y - lo_y) or 1.0
    grid = [[" "] * width for _ in range(height)]
    fronts = {id(p) for p in front}

    def put(p, ch):
        x = int((p.latency_s - lo_x) / dx * (width - 1))
        y = int((p.throughput - lo_y) / dy * (height - 1))
        grid[height - 1 - y][x] = ch

    for p in points:
        put(p, "·")
    for p in front:
        put(p, "O")
    lines = [title, f"thr {hi_y:8.2f} ┐"]
    lines += ["".join(r) for r in grid]
    lines.append(f"thr {lo_y:8.2f} ┘  lat {lo_x*1e3:.1f}ms … {hi_x*1e3:.1f}ms"
                 "   (O = Pareto front)")
    return "\n".join(lines)
