"""Fault-tolerance drills: detection latency, restart+replay cost,
degraded-capacity failover, and post-recovery parity.

The study behind ``BENCH_fault.json``: a supervised 2-stage pipeline
streams batches while a scripted :class:`FaultPlan` SIGKILLs a worker
mid-stream.  Two drills:

* ``restart`` (per transport) — the killed stage has no spare replica:
  the supervisor tears the stage down, respawns it, replays the WARMUP
  fence and the Session's unacked in-flight window.  Reported: failure
  detection latency, restart time, replay time, batches replayed, and
  bit-parity of the recovered stream against single-process references.
* ``failover`` (shmem) — the killed worker is one lane of an r=2
  replicated stage: the pipeline sheds the lane and continues degraded
  at r-1 (capacity fraction 0.5) until the background restaff returns
  it to full strength.  Reported: the degraded capacity fraction and
  the whole-run throughput fraction vs an undisturbed run.

    PYTHONPATH=src python -m benchmarks.fault_bench [--smoke] [--check]

``--smoke`` shrinks the stream (< 90 s, the Makefile ``bench-fault``
target) and still writes the JSON.  ``--check`` runs a fresh smoke
measurement and gates recovery-health invariants — detection under
``CHECK_MAX_DETECT_S``, restart+replay under ``CHECK_MAX_RECOVER_S``,
exact parity, and the r=2 failover running the degraded window at
exactly half capacity — the ``make bench-fault-check`` / ``make fast``
regression gate.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

BENCH_JSON = Path("BENCH_fault.json")

TRANSPORTS = ("socket", "shmem")

# --check gates: generous under ambient load, tight enough that a
# supervisor that polls lazily (detection) or re-warms from scratch
# per batch (replay) fails loudly
CHECK_MAX_DETECT_S = 3.0
CHECK_MAX_RECOVER_S = 30.0           # restart (respawn + jit + fence) + replay
CHECK_FAILOVER_CAPACITY = 0.5        # r=2 minus one lane


def _tiny_model():
    from repro.models.cnn.layers import (Conv2D, Flatten, Linear, Pool,
                                         ReLU, Sequential)
    from repro.models.cnn.zoo import CNNModel
    blocks = [
        ("conv0", Sequential([Conv2D(3, 8, 3, 1, 1), ReLU()])),
        ("conv1", Sequential([Conv2D(8, 8, 3, 1, 1), ReLU()])),
        ("pool", Pool("max", 2, 2)),
        ("conv2", Sequential([Conv2D(8, 16, 3, 1, 1), ReLU()])),
        ("head", Sequential([Flatten(), Linear(16 * 16 * 16, 10)])),
    ]
    return CNNModel("tinycnn", blocks, input_hw=32)


def _stream(model, params, xs, transport, plan=None, replicas=None):
    """Run the stream; return (outputs, elapsed_s, recovery records)."""
    import numpy as np

    from repro.core.devices import LAN_PI_GPU
    from repro.runtime.edge import EdgePipeline
    from repro.runtime.faults import drain_recoveries

    drain_recoveries()
    pipe = EdgePipeline(model, params, 2, [LAN_PI_GPU], transport=transport,
                        replicas=replicas, fault_plan=plan,
                        supervise=True, stall_timeout_s=2.0, timeout_s=120)
    with pipe:
        pipe.warmup(xs[0])
        with pipe.session() as s:
            t0 = time.perf_counter()
            for x in xs:
                s.submit(x)
            outs = s.drain()
            elapsed = time.perf_counter() - t0
    return ([np.asarray(y) for y in outs], float(elapsed),
            drain_recoveries())


def _parity(outs, refs) -> bool:
    import numpy as np
    return (len(outs) == len(refs)
            and all(np.allclose(r, y, atol=1e-5)
                    for r, y in zip(refs, outs)))


def _measure(smoke: bool) -> tuple[list[str], dict]:
    import jax
    import numpy as np

    from repro.runtime.faults import FaultPlan

    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    n = 8 if smoke else 24
    xs = [np.asarray(jax.random.normal(jax.random.PRNGKey(100 + i),
                                       (2, 32, 32, 3))) for i in range(n)]
    refs = [np.asarray(model.apply(params, x)) for x in xs]
    kill_at = min(3, n - 1)

    rows: list[str] = []
    results: dict = {"model": model.name, "batch": 2, "n_batches": n,
                     "kill_at_seq": kill_at, "restart": {}, "failover": {}}

    print(f"== recovery drills ({n} batches, kill at seq {kill_at}) ==")
    for transport in TRANSPORTS:
        plan = FaultPlan().kill_worker(stage=1, at_seq=kill_at)
        outs, elapsed, recs = _stream(model, params, xs, transport,
                                      plan=plan)
        rec = next((r for r in recs if r.kind == "restart"), None)
        assert rec is not None, f"{transport}: no restart recovery recorded"
        m = {
            "transport": transport,
            "detect_s": rec.detect_s,
            "restart_s": rec.restart_s,
            "replay_s": rec.replay_s,
            "recover_s": rec.restart_s + rec.replay_s,
            "batches_replayed": rec.batches_replayed,
            "parity": _parity(outs, refs),
            "elapsed_s": elapsed,
        }
        results["restart"][transport] = m
        print(f"  restart/{transport:>6}: detect {m['detect_s'] * 1e3:6.0f} ms, "
              f"restart {m['restart_s'] * 1e3:6.0f} ms, "
              f"replay {m['replay_s'] * 1e3:6.0f} ms "
              f"({m['batches_replayed']} batches), parity={m['parity']}")
        rows.append(f"fault/restart_{transport},{m['recover_s']:.3f},"
                    f"detect_s={m['detect_s']:.3f}")

    # failover drill: one lane of an r=2 stage dies; the run continues
    # degraded and restaffs in the background
    baseline_outs, baseline_s, _ = _stream(model, params, xs, "shmem",
                                           replicas=(1, 2))
    plan = FaultPlan().kill_worker(stage=1, at_seq=kill_at, lane=1)
    outs, elapsed, recs = _stream(model, params, xs, "shmem", plan=plan,
                                  replicas=(1, 2))
    fo = next((r for r in recs if r.kind == "failover"), None)
    m = {
        "transport": "shmem",
        "replicas": [1, 2],
        "recovered": fo is not None,
        "degraded_capacity": fo.degraded_capacity if fo else None,
        "detect_s": fo.detect_s if fo else None,
        "restaffed": any(r.kind == "restaff" for r in recs),
        "parity": _parity(outs, baseline_outs) and _parity(outs, refs),
        "throughput_fraction": baseline_s / elapsed if elapsed else 0.0,
        "elapsed_s": elapsed,
        "baseline_s": baseline_s,
    }
    results["failover"]["shmem"] = m
    print(f"  failover/shmem: capacity {m['degraded_capacity']}, "
          f"restaffed={m['restaffed']}, parity={m['parity']}, "
          f"throughput fraction {m['throughput_fraction']:.2f}")
    rows.append(f"fault/failover_shmem,{m['throughput_fraction']:.3f},"
                f"capacity={m['degraded_capacity']}")
    return rows, results


def run(smoke: bool = False, out_path: Path = BENCH_JSON) -> list[str]:
    rows, results = _measure(smoke)
    out_path.write_text(json.dumps(results, indent=1))
    print(f"[wrote {out_path}]")
    return rows


def check() -> int:
    """Fresh smoke run gated on recovery-health invariants.  Retries:
    one unlucky scheduling window is not a regression."""
    for attempt in (1, 2, 3):
        _, fresh = _measure(smoke=True)
        bad: list[str] = []
        for transport, m in fresh["restart"].items():
            if not m["parity"]:
                bad.append(f"restart/{transport}: recovered stream is not "
                           "bit-identical to the references")
            if m["detect_s"] > CHECK_MAX_DETECT_S:
                bad.append(f"restart/{transport}: detection took "
                           f"{m['detect_s']:.2f}s > {CHECK_MAX_DETECT_S}s")
            if m["recover_s"] > CHECK_MAX_RECOVER_S:
                bad.append(f"restart/{transport}: restart+replay took "
                           f"{m['recover_s']:.2f}s > {CHECK_MAX_RECOVER_S}s")
            if m["batches_replayed"] < 1:
                bad.append(f"restart/{transport}: no in-flight batches "
                           "replayed — the resubmit buffer is dead")
        fo = fresh["failover"]["shmem"]
        if not fo["recovered"]:
            bad.append("failover/shmem: lane death did not take the "
                       "failover path")
        elif fo["degraded_capacity"] != CHECK_FAILOVER_CAPACITY:
            bad.append(f"failover/shmem: degraded capacity "
                       f"{fo['degraded_capacity']} != "
                       f"{CHECK_FAILOVER_CAPACITY}")
        if not fo["parity"]:
            bad.append("failover/shmem: degraded stream lost exactness")
        if not bad:
            print("[check] OK — recovery is prompt, bounded, and exact")
            return 0
        print(f"[check] attempt {attempt}: {len(bad)} problem(s)")
        for b in bad:
            print(f"    {b}")
    print("[check] FAIL — fault recovery regressed")
    return 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small run (< 90 s) that still writes "
                         "BENCH_fault.json")
    ap.add_argument("--check", action="store_true",
                    help="fresh smoke run gated on detection/recovery "
                         "bounds and parity (no overwrite)")
    args = ap.parse_args()
    if args.check:
        sys.exit(check())
    rows = run(smoke=args.smoke)
    print("\nname,value,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
