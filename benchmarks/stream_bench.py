"""Streaming-session benchmark: pipelined steady state + migration dip.

Two views of the Session API, both written to ``BENCH_stream.json``:

  * **steady** — pipelined steady-state throughput (img/s) per
    transport, measured twice over the same pipeline: through a raw
    ``Session`` (PinnedController) and through the legacy ``stream()``
    shim.  The ratio is the acceptance number for the API redesign —
    the shim must cost nothing (it *is* a session underneath).
  * **migration** — a mid-stream ``Session.migrate`` under each policy
    (``drain`` flushes the pipeline first, ``drop`` sends the RECONFIG
    token chasing the in-flight batches): per-batch windowed throughput
    around the move gives the dip (fraction of steady state) and the
    recovery time (back above 90 % of steady).

    PYTHONPATH=src python -m benchmarks.stream_bench [--smoke] [--check]

``--smoke`` shrinks batch counts and runs the migration study on the
emulated transport only (< 30 s, the ``make bench-stream`` target).
``--check`` runs a fresh smoke measurement and diffs it against the
*committed* ``BENCH_stream.json`` (no overwrite), failing on a large
steady-state regression — the ``make bench-stream-check`` / ``make
fast`` gate.  Process-transport numbers are normalized by the same-run
emulated control so ambient load on a shared host does not read as a
code regression.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from benchmarks.transport_bench import _tiny_model  # same reference model

BENCH_JSON = Path("BENCH_stream.json")

TRANSPORTS = ("emulated", "socket", "shmem")
POLICIES = ("drain", "drop")
CUT, CUT2 = 2, 3

# --check tolerances: a transport's fresh steady-state img/s must stay
# above committed / CHECK_REL after normalizing by the same-run
# emulated control; the session-vs-stream parity ratio is a within-run
# invariant (the shim *is* a session underneath, so any true drift is a
# structural regression — an accidental barrier or per-batch overhead
# in one path).  Parity is measured as the median of adjacent-in-time
# trial ratios, which cancels most ambient load; observed spread on a
# loaded 2-core host is ~0.6–1.25, and the gate retries 3×, so the
# bound below flags a persistent ~1.4× divergence without flaking on
# one unlucky window.
CHECK_REL = 1.6
CHECK_PARITY = (0.7, 1.43)
CHECK_MAX_LOAD = 1.6


def _pipe(model, params, transport):
    from repro.core.devices import LOOPBACK
    from repro.runtime import EdgePipeline
    return EdgePipeline(model, params, CUT, [LOOPBACK], transport=transport)


def steady_state(model, params, x, transport: str, n_batches: int,
                 trials: int = 3) -> dict:
    """Pipelined img/s via a raw Session vs the stream() shim.

    Trials interleave the two modes and the best (= least-preempted)
    run per mode is reported — on a small shared host the run-to-run
    scheduler noise is far larger than any session-vs-shim difference,
    and the best-of is the intrinsic cost of each path."""
    batch = x.shape[0]
    sess, strm = [], []
    with _pipe(model, params, transport) as pipe:
        pipe.warmup(x)
        pipe.stream(x, max(n_batches // 4, 2))     # settle caches/pages
        for _ in range(trials):
            with pipe.session(keep_results=False) as s:
                t0 = time.perf_counter()
                for _ in range(n_batches):
                    s.submit(x)
                s.drain()
                sess.append(n_batches * batch / (time.perf_counter() - t0))
            strm.append(n_batches * batch / pipe.stream(x, n_batches))
    return {
        "session_ips": float(max(sess)),
        "stream_ips": float(max(strm)),
        # the acceptance number: stream() is a thin shim over Session,
        # so this must sit near 1.0.  Median of per-trial (adjacent in
        # time) ratios — adjacent runs share the ambient load, so the
        # quotient cancels most of the scheduler noise the best-of
        # numbers above cannot
        "ratio": float(np.median([a / max(b, 1e-9)
                                  for a, b in zip(sess, strm)])),
    }


def migration_dip(model, params, x, transport: str, policy: str,
                  n_batches: int, cost_s: float = 0.05) -> dict:
    """Windowed throughput around a mid-stream migration → dip depth +
    recovery time."""
    batch = x.shape[0]
    with _pipe(model, params, transport) as pipe:
        pipe.warmup(x)
        pipe.stream(x, max(n_batches // 4, 2))
        with pipe.session(keep_results=False, inflight=4,
                          policy=policy, window=6) as s:
            for i in range(n_batches):
                if i == n_batches // 2:
                    s.migrate(CUT2, cost_s=cost_s)
                s.submit(x)
            s.drain()
        recs = s.records
        t_mig = pipe.migrations[-1][0]
    mid = n_batches // 2
    pre = [r.throughput for r in recs[:mid] if r.throughput > 0]
    post = [r for r in recs[mid:] if r.throughput > 0]
    steady = float(np.median(pre)) if pre else 0.0
    dip = float(min((r.throughput for r in post), default=0.0))
    recovery_s = None
    for r in post:
        if r.throughput >= 0.9 * steady:
            recovery_s = max(float(r.t_s - t_mig), 0.0)
            break
    return {
        "policy": policy,
        "steady_ips": steady,
        "dip_ips": dip,
        "dip_frac": float(dip / max(steady, 1e-9)),
        "migration_cost_s": cost_s,
        "recovery_s": recovery_s,
        "batch": batch,
    }


def _measure(smoke: bool, write: bool = True,
             out_path: Path = BENCH_JSON,
             steady_only: bool = False) -> tuple[list[str], dict]:
    import jax
    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    n_steady = 24 if smoke else 80
    n_mig = 36 if smoke else 80
    mig_transports = () if steady_only else (
        ("emulated",) if smoke else TRANSPORTS)
    if smoke and not steady_only:
        print("[smoke: migration study on the emulated transport only — "
              "run without --smoke for the full matrix]")

    rows: list[str] = []
    results = {"model": model.name, "batch": 2, "cut": CUT,
               "n_batches": n_steady, "steady": {}, "migration": {}}

    print("== pipelined steady state (session vs stream() shim) ==")
    for transport in TRANSPORTS:
        # the committed (full) run needs enough adjacent-pair samples
        # for the parity median to converge — single ratios swing
        # 0.4–2× with ambient load on a small host, the 9-trial median
        # sits at ~1.0
        r = steady_state(model, params, x, transport, n_steady,
                         trials=3 if smoke else 9)
        results["steady"][transport] = r
        print(f"  {transport:>8}  session {r['session_ips']:8.1f} img/s  "
              f"stream-shim {r['stream_ips']:8.1f} img/s  "
              f"ratio {r['ratio']:.3f}")
        rows.append(f"stream/steady_{transport},{r['session_ips']:.3f},"
                    f"ratio={r['ratio']:.3f}")

    if mig_transports:
        print("== mid-stream migration: throughput dip and recovery ==")
    for transport in mig_transports:
        for policy in POLICIES:
            r = migration_dip(model, params, x, transport, policy, n_mig)
            results["migration"][f"{transport}/{policy}"] = r
            rec = ("n/a" if r["recovery_s"] is None
                   else f"{r['recovery_s'] * 1e3:7.1f} ms")
            print(f"  {transport:>8}/{policy:<5}  steady "
                  f"{r['steady_ips']:8.1f} img/s  dip "
                  f"{r['dip_frac'] * 100:5.1f}%  recovery {rec}")
            rows.append(f"stream/migrate_{transport}_{policy},"
                        f"{r['steady_ips']:.3f},"
                        f"dip_frac={r['dip_frac']:.3f}")
    if write:
        out_path.write_text(json.dumps(results, indent=1))
        print(f"[wrote {out_path}]")
    return rows, results


def stream_throughput(smoke: bool = False) -> list[str]:
    """Harness entrypoint (benchmarks.run): measure + write the JSON."""
    rows, _ = _measure(smoke=smoke)
    return rows


def _check_one(fresh: dict, ref: dict) -> tuple[list[str], float]:
    bad: list[str] = []
    f_st, r_st = fresh.get("steady", {}), ref.get("steady", {})
    # emulated is the in-run load control: its throughput is modeled
    # sleeps + tiny-model compute, and ambient load moves it together
    # with the process transports
    load = (r_st.get("emulated", {}).get("session_ips", 1.0)
            / max(f_st.get("emulated", {}).get("session_ips", 1.0), 1e-9))
    for transport in TRANSPORTS:
        f, r = f_st.get(transport), r_st.get(transport)
        if not f or not r:
            bad.append(f"steady/{transport}: missing from fresh or ref")
            continue
        allowed = r["session_ips"] / load / CHECK_REL
        if f["session_ips"] < allowed:
            bad.append(
                f"steady/{transport}: {f['session_ips']:.1f} img/s vs "
                f"committed {r['session_ips']:.1f} / load x{load:.2f} = "
                f"{allowed:.1f} allowed")
        lo, hi = CHECK_PARITY
        if not (lo <= f["ratio"] <= hi):
            bad.append(f"parity/{transport}: session/stream ratio "
                       f"{f['ratio']:.3f} outside [{lo}, {hi}]")
    return bad, load


def check(ref_path: Path = BENCH_JSON) -> int:
    """Fresh smoke measurement vs the committed reference → exit code.
    Retries before failing; skips loudly when the host is starved."""
    if not ref_path.exists():
        print(f"[check] no committed {ref_path}; run the bench first")
        return 2
    ref = json.loads(ref_path.read_text())
    if not ref.get("steady"):
        print(f"[check] committed {ref_path} has no steady block; "
              f"regenerate it with `make bench-stream` first")
        return 2
    loads: list[float] = []
    for attempt in (1, 2, 3):
        # the gate reads only the steady block — skip the (slow)
        # migration-dip study entirely on every attempt
        _, fresh = _measure(smoke=True, write=False, steady_only=True)
        bad, load = _check_one(fresh, ref)
        loads.append(load)
        if not bad:
            print(f"[check] OK — no steady-state regression vs {ref_path}")
            return 0
        print(f"[check] attempt {attempt}: {len(bad)} regression(s) "
              f"(emulated control at x{load:.2f} committed)")
        for b in bad:
            print(f"    {b}")
    if min(loads) > CHECK_MAX_LOAD:
        print(f"[check] SKIPPED — the emulated control ran >= "
              f"x{min(loads):.1f} slower than committed on every attempt: "
              f"the host is starved and wall-clock throughput here cannot "
              f"tell a regression from scheduler starvation.")
        return 0
    print(f"[check] FAIL — steady-state throughput regressed vs {ref_path}")
    return 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run (< 30 s) that still writes "
                         "BENCH_stream.json")
    ap.add_argument("--check", action="store_true",
                    help="measure fresh and diff against the committed "
                         "BENCH_stream.json (no overwrite)")
    args = ap.parse_args()
    if args.check:
        sys.exit(check())
    rows = stream_throughput(smoke=args.smoke)
    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
