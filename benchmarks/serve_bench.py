"""ServeGate benchmark: multi-tenant coalescing gain and tail latency.

Closed-loop tenants (each keeps exactly one request outstanding) served
through the :class:`~repro.runtime.serve.Gateway`, written to
``BENCH_serve.json``:

  * **aggregate** — served requests/s per tenant count.  The gateway
    pads every micro-batch to ``max_batch`` rows (the deterministic-
    batching contract), so a solo tenant pays a full batch per request
    while 8 tenants amortize the same batch across 8 requests — the
    coalescing gain is structural, not a scheduling accident.
  * **tail** — per-request p50/p99 (queue + service, the SLO quantity)
    and mean micro-batch occupancy from the per-request QoS log.

The acceptance gate is *within-run* (both sides of each ratio see the
same host, so ambient load cancels):

  * 8-tenant aggregate >= ``GATE_SPEEDUP`` x single-tenant aggregate;
  * 8-tenant p99 latency <= ``GATE_TAIL`` x single-tenant p50.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] [--check]

``--smoke`` shrinks request counts and skips the process-transport
row (< 30 s, the ``make bench-serve`` target).  ``--check`` runs a
fresh smoke measurement and asserts the gates (retrying before
failing) without overwriting the committed JSON — the ``make
bench-serve-check`` / ``make fast`` gate.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from benchmarks.transport_bench import _tiny_model  # same reference model

BENCH_JSON = Path("BENCH_serve.json")

CUT = 2
MAX_BATCH = 8
# generous coalescing window: the closed-loop resubmit burst takes
# microseconds, so every micro-batch gathers the full tenant fan-in
# even on a preempted host
BATCH_WINDOW_S = 0.02

GATE_SPEEDUP = 3.0          # 8-tenant aggregate vs single-tenant
GATE_TAIL = 5.0             # 8-tenant p99 vs single-tenant p50


def _pipe(model, params, transport):
    from repro.core.devices import LOOPBACK
    from repro.runtime import EdgePipeline
    return EdgePipeline(model, params, CUT, [LOOPBACK],
                        transport=transport, timeout_s=120)


def serve_closed_loop(model, params, x_row, n_tenants: int,
                      reqs_per_tenant: int,
                      transport: str = "emulated") -> dict:
    """Closed loop: every tenant resubmits the moment its previous
    request completes, so offered load scales with the tenant count and
    each micro-batch coalesces up to ``n_tenants`` rows."""
    from repro.core.scenarios import TenantSpec
    from repro.runtime import Gateway

    names = [f"t{i}" for i in range(n_tenants)]
    # distinct rows per tenant so demux bugs would surface as wrong data
    xs = {n: np.asarray(x_row) + np.float32(i * 1e-3)
          for i, n in enumerate(names)}
    left = {n: reqs_per_tenant for n in names}
    total = n_tenants * reqs_per_tenant
    with _pipe(model, params, transport) as pipe:
        pipe.warmup(np.concatenate([np.asarray(x_row)] * MAX_BATCH, 0))
        with Gateway(pipe, [TenantSpec(n, slo_s=30.0) for n in names],
                     max_batch=MAX_BATCH,
                     batch_window_s=BATCH_WINDOW_S, inflight=2) as gw:
            done = 0
            t0 = time.perf_counter()
            for n in names:                   # prime: one in flight each
                gw.submit(n, xs[n])
                left[n] -= 1
            while done < total:
                served = gw.poll(block=True)
                if not served and not gw.pending:
                    raise RuntimeError("gateway went idle with "
                                       f"{total - done} requests unserved")
                for tenant, _req_id, _val in served:
                    done += 1
                    if left[tenant]:
                        left[tenant] -= 1
                        gw.submit(tenant, xs[tenant])
            wall = time.perf_counter() - t0
            qos = gw.drain_qos()
    assert len(qos) == total, (len(qos), total)
    lats = np.asarray([r.latency_s for r in qos])
    return {
        "transport": transport,
        "n_tenants": n_tenants,
        "reqs_per_tenant": reqs_per_tenant,
        "aggregate_ips": total / wall,
        "p50_s": float(np.percentile(lats, 50)),
        "p99_s": float(np.percentile(lats, 99)),
        "occupancy": float(np.mean([r.occupancy for r in qos])),
        "coalesced": float(np.mean([r.coalesced for r in qos])),
        "j_per_request": float(np.mean([r.energy_j for r in qos])),
    }


def _gates(results: dict) -> list[str]:
    """The within-run acceptance gates over a measured tenant sweep."""
    bad: list[str] = []
    solo = results["tenants"].get("1")
    octet = results["tenants"].get("8")
    if not solo or not octet:
        return ["missing the 1-tenant or 8-tenant measurement"]
    speedup = octet["aggregate_ips"] / max(solo["aggregate_ips"], 1e-9)
    if speedup < GATE_SPEEDUP:
        bad.append(f"aggregate: 8-tenant {octet['aggregate_ips']:.1f} req/s "
                   f"is only {speedup:.2f}x solo "
                   f"{solo['aggregate_ips']:.1f} (need >= {GATE_SPEEDUP}x)")
    tail = octet["p99_s"] / max(solo["p50_s"], 1e-9)
    if tail > GATE_TAIL:
        bad.append(f"tail: 8-tenant p99 {octet['p99_s'] * 1e3:.1f} ms is "
                   f"{tail:.2f}x solo p50 {solo['p50_s'] * 1e3:.1f} ms "
                   f"(need <= {GATE_TAIL}x)")
    return bad


def _measure(smoke: bool, write: bool = True,
             out_path: Path = BENCH_JSON) -> tuple[list[str], dict]:
    import jax
    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    x_row = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                         (1, 32, 32, 3)))
    counts = (1, 8) if smoke else (1, 2, 4, 8)
    reqs = 32 if smoke else 128

    rows: list[str] = []
    results = {"model": model.name, "cut": CUT, "max_batch": MAX_BATCH,
               "reqs_per_tenant": reqs, "tenants": {}}

    print("== closed-loop multi-tenant serving (emulated) ==")
    for n in counts:
        r = serve_closed_loop(model, params, x_row, n, reqs)
        results["tenants"][str(n)] = r
        print(f"  {n:>2} tenants  {r['aggregate_ips']:8.1f} req/s  "
              f"p50 {r['p50_s'] * 1e3:6.1f} ms  p99 {r['p99_s'] * 1e3:6.1f} "
              f"ms  occupancy {r['occupancy']:.2f}")
        rows.append(f"serve/aggregate_{n}t,{r['aggregate_ips']:.3f},"
                    f"p99_ms={r['p99_s'] * 1e3:.2f}")

    solo = results["tenants"]["1"]
    octet = results["tenants"]["8"]
    results["speedup_8t"] = octet["aggregate_ips"] / solo["aggregate_ips"]
    results["tail_8t_vs_solo_p50"] = octet["p99_s"] / solo["p50_s"]
    print(f"  coalescing gain {results['speedup_8t']:.2f}x  "
          f"tail {results['tail_8t_vs_solo_p50']:.2f}x solo p50")
    rows.append(f"serve/speedup_8t,{results['speedup_8t']:.3f},"
                f"gate>={GATE_SPEEDUP}")

    if not smoke:
        # informational: the same octet workload over a real transport
        print("== 8 tenants over shmem (informational) ==")
        r = serve_closed_loop(model, params, x_row, 8, reqs, "shmem")
        results["shmem_8t"] = r
        print(f"   8 tenants  {r['aggregate_ips']:8.1f} req/s  "
              f"p99 {r['p99_s'] * 1e3:6.1f} ms")
        rows.append(f"serve/aggregate_8t_shmem,{r['aggregate_ips']:.3f},"
                    f"p99_ms={r['p99_s'] * 1e3:.2f}")

    for b in _gates(results):
        print(f"  [gate] {b}")
    if write:
        out_path.write_text(json.dumps(results, indent=1))
        print(f"[wrote {out_path}]")
    return rows, results


def serve_throughput(smoke: bool = False) -> list[str]:
    """Harness entrypoint (benchmarks.run): measure + write the JSON."""
    rows, _ = _measure(smoke=smoke)
    return rows


def check(ref_path: Path = BENCH_JSON) -> int:
    """Fresh smoke measurement; assert the within-run gates → exit
    code.  No load normalization needed: both sides of each gate ratio
    come from the same run on the same host."""
    if not ref_path.exists():
        print(f"[check] no committed {ref_path}; run the bench first")
        return 2
    ref = json.loads(ref_path.read_text())
    if _gates(ref):
        print(f"[check] committed {ref_path} fails its own gates; "
              f"regenerate it with `make bench-serve`")
        return 2
    for attempt in (1, 2, 3):
        _, fresh = _measure(smoke=True, write=False)
        bad = _gates(fresh)
        if not bad:
            print(f"[check] OK — coalescing gain "
                  f"{fresh['speedup_8t']:.2f}x (gate {GATE_SPEEDUP}x), "
                  f"tail {fresh['tail_8t_vs_solo_p50']:.2f}x solo p50 "
                  f"(gate {GATE_TAIL}x)")
            return 0
        print(f"[check] attempt {attempt}: {len(bad)} gate failure(s)")
        for b in bad:
            print(f"    {b}")
    print(f"[check] FAIL — the serving gates did not pass on any attempt")
    return 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run (< 30 s) that still writes "
                         "BENCH_serve.json")
    ap.add_argument("--check", action="store_true",
                    help="fresh smoke measurement + within-run gates "
                         "(no overwrite)")
    args = ap.parse_args()
    if args.check:
        sys.exit(check())
    rows = serve_throughput(smoke=args.smoke)
    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
