"""Replicated-stage throughput: img/s vs replica count per transport.

The study behind ``BENCH_replica.json``: a 3-stage pipeline whose
middle stage is the clear bottleneck (``stage_pace_s`` emulates a
device ~12x slower than its neighbours, the compute-side twin of the
emulated link pacing) is run at r ∈ {1, 2, 3} replicas of that stage
over both real process transports.  The fan-out dispatcher stripes
batches across the replica lanes and the fan-in merge restores seq
order, so steady-state throughput should scale with r until the
neighbour stages become the new bottleneck.

    PYTHONPATH=src python -m benchmarks.replica_bench [--smoke] [--check]

``--smoke`` shrinks the batch count (< 60 s, the Makefile
``bench-replica`` target) and still writes the JSON.  ``--check`` runs
a fresh smoke measurement and gates against *within-run* invariants
instead of committed wall-clock numbers (replication wins are ratios
of paced sleeps, so ambient load mostly cancels): r=2 must hold a
>= 1.5x throughput win over r=1 and r=3 must not fall below r=2, on
both transports — the ``make bench-replica-check`` / ``make fast``
regression gate.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

BENCH_JSON = Path("BENCH_replica.json")

CUTS = (2, 3)
# middle stage ~12x the edge stages: replicating it must pay off until
# r pushes its effective cycle under the neighbours'
PACE_S = (0.004, 0.048, 0.004)
R_VALUES = (1, 2, 3)
TRANSPORTS = ("socket", "shmem")

# --check gate: paced sleeps overlap across replicas regardless of host
# load, so the win is load-insensitive — but keep a margin under the
# ideal 2.0x for fill/drain transients and scheduler jitter
CHECK_MIN_SPEEDUP_R2 = 1.5
CHECK_MONOTONE_SLACK = 0.97          # r=3 may tie r=2, not regress


def _tiny_model():
    from repro.models.cnn.layers import (Conv2D, Flatten, Linear, Pool,
                                         ReLU, Sequential)
    from repro.models.cnn.zoo import CNNModel
    blocks = [
        ("conv0", Sequential([Conv2D(3, 8, 3, 1, 1), ReLU()])),
        ("conv1", Sequential([Conv2D(8, 8, 3, 1, 1), ReLU()])),
        ("pool", Pool("max", 2, 2)),
        ("conv2", Sequential([Conv2D(8, 16, 3, 1, 1), ReLU()])),
        ("head", Sequential([Flatten(), Linear(16 * 16 * 16, 10)])),
    ]
    return CNNModel("tinycnn", blocks, input_hw=32)


def _run_one(model, params, x, transport: str, r: int,
             n_batches: int) -> dict:
    from repro.core.devices import LAN_PI_GPU
    from repro.runtime.edge import EdgePipeline

    batch = int(x.shape[0])
    with EdgePipeline(model, params, CUTS, [LAN_PI_GPU, LAN_PI_GPU],
                      transport=transport, replicas=(1, r, 1),
                      stage_pace_s=PACE_S) as pipe:
        pipe.warmup(x)                        # jit-warms every replica
        with pipe.session(inflight=4 + 2 * r) as s:
            for _ in range(2 * r):            # settle each replica lane
                s.submit(x)
            s.drain()
            t0 = time.perf_counter()
            for _ in range(n_batches):
                s.submit(x)
            got = s.drain()
            elapsed = time.perf_counter() - t0
    assert len(got) == n_batches, f"lost results: {len(got)}/{n_batches}"
    return {
        "replicas": r,
        "transport": transport,
        "n_batches": n_batches,
        "elapsed_s": float(elapsed),
        "img_s": float(batch * n_batches / elapsed),
        "batch_ms": float(elapsed / n_batches * 1e3),
    }


def _measure(smoke: bool) -> tuple[list[str], dict]:
    import jax

    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    n_batches = 16 if smoke else 40

    rows: list[str] = []
    results: dict = {"model": model.name, "batch": 2, "cuts": list(CUTS),
                     "stage_pace_s": list(PACE_S), "n_batches": n_batches,
                     "results": {}, "speedup": {}}
    print(f"== img/s vs replica count (paced bottleneck stage, "
          f"{n_batches} batches) ==")
    for transport in TRANSPORTS:
        per_r: dict[str, dict] = {}
        for r in R_VALUES:
            m = _run_one(model, params, x, transport, r, n_batches)
            per_r[str(r)] = m
            gain = m["img_s"] / per_r["1"]["img_s"]
            print(f"  {transport:>6} r={r}: {m['img_s']:7.1f} img/s  "
                  f"({m['batch_ms']:.1f} ms/batch, {gain:.2f}x)")
            rows.append(f"replica/{transport}_r{r},{m['img_s']:.3f},"
                        f"batch_ms={m['batch_ms']:.3f}")
        results["results"][transport] = per_r
        results["speedup"][transport] = {
            str(r): per_r[str(r)]["img_s"] / per_r["1"]["img_s"]
            for r in R_VALUES}
        s2, s3 = (results["speedup"][transport]["2"],
                  results["speedup"][transport]["3"])
        print(f"  {transport:>6} speedup: r2 {s2:.2f}x, r3 {s3:.2f}x")
    return rows, results


def run(smoke: bool = False, out_path: Path = BENCH_JSON) -> list[str]:
    rows, results = _measure(smoke)
    out_path.write_text(json.dumps(results, indent=1))
    print(f"[wrote {out_path}]")
    return rows


def check() -> int:
    """Fresh smoke run gated on within-run replica-win invariants.
    Retries: one unlucky scheduling window is not a regression."""
    for attempt in (1, 2, 3):
        _, fresh = _measure(smoke=True)
        bad: list[str] = []
        for transport in TRANSPORTS:
            sp = fresh["speedup"][transport]
            if sp["2"] < CHECK_MIN_SPEEDUP_R2:
                bad.append(f"{transport}: r=2 speedup {sp['2']:.2f}x < "
                           f"{CHECK_MIN_SPEEDUP_R2}x")
            if sp["3"] < sp["2"] * CHECK_MONOTONE_SLACK:
                bad.append(f"{transport}: r=3 speedup {sp['3']:.2f}x fell "
                           f"below r=2 ({sp['2']:.2f}x)")
        if not bad:
            print("[check] OK — replica fan-out holds its throughput win")
            return 0
        print(f"[check] attempt {attempt}: {len(bad)} problem(s)")
        for b in bad:
            print(f"    {b}")
    print("[check] FAIL — replicated stages no longer scale throughput")
    return 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small run (< 60 s) that still writes "
                         "BENCH_replica.json")
    ap.add_argument("--check", action="store_true",
                    help="fresh smoke run gated on the r=2 >= 1.5x and "
                         "monotone-r=3 invariants (no overwrite)")
    args = ap.parse_args()
    if args.check:
        sys.exit(check())
    rows = run(smoke=args.smoke)
    print("\nname,img_s,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
