"""Transport-overhead comparison: modeled vs. measured hops.

For the same tiny model + cut, runs a 2-stage pipeline over every
transport × framing combination and reports the per-hop transfer cost:

  * ``emulated``   — the modeled loopback (Link math injected as sleep),
  * ``socket``     — real TCP between worker processes on loopback,
  * ``shmem``      — the shared-memory ring between processes,

each under the ``lightweight`` (header + raw tensor bytes) and ``rpc``
(full pickle round trip per hop + per-block dispatch) framings — the
paper's backend study, now with *measured* numbers for the real
channels.  Results go to ``BENCH_transport.json`` plus the harness CSV.

    PYTHONPATH=src python -m benchmarks.transport_bench [--smoke]

``--smoke`` shrinks the batch count (< 30 s, the Makefile
``bench-transport`` target) and still writes BENCH_transport.json.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from .common import emit

BENCH_JSON = Path("BENCH_transport.json")

COMBOS = [("emulated", "lightweight"), ("emulated", "rpc"),
          ("socket", "lightweight"), ("socket", "rpc"),
          ("shmem", "lightweight"), ("shmem", "rpc")]


def _one_combo(model, params, x, transport: str, backend: str,
               n_batches: int) -> dict:
    from repro.core.devices import LOOPBACK
    from repro.runtime.edge import EdgePipeline

    with EdgePipeline(model, params, 2, [LOOPBACK], backend=backend,
                      transport=transport) as pipe:
        pipe.warmup(x)
        pipe.run_one(x)                       # settle caches / first-touch
        pipe.nets[0].drain_observations()
        lats = []
        for _ in range(n_batches):
            _, lat, _ = pipe.run_one(x)
            lats.append(lat)
        recs = [r for r in pipe.nets[0].drain_observations() if r.nbytes > 0]
        return {
            "transport": transport,
            "backend": backend,
            "measured": transport != "emulated",
            # medians: lone-batch transfers on a small shared host are
            # heavy-tailed (scheduler preemption), and the tail is not
            # what the framing comparison is about
            "hop_us": float(np.median([r.elapsed_s for r in recs]) * 1e6),
            "hop_us_min": float(min(r.elapsed_s for r in recs) * 1e6),
            "nbytes": int(recs[0].nbytes),
            "latency_ms": float(np.median(lats) * 1e3),
        }


def _tiny_model():
    """A 5-block CNN that jit-compiles in a blink — the hop cost is the
    thing under test, not the compute."""
    from repro.models.cnn.layers import (Conv2D, Flatten, Linear, Pool,
                                         ReLU, Sequential)
    from repro.models.cnn.zoo import CNNModel
    blocks = [
        ("conv0", Sequential([Conv2D(3, 8, 3, 1, 1), ReLU()])),
        ("conv1", Sequential([Conv2D(8, 8, 3, 1, 1), ReLU()])),
        ("pool", Pool("max", 2, 2)),
        ("conv2", Sequential([Conv2D(8, 16, 3, 1, 1), ReLU()])),
        ("head", Sequential([Flatten(), Linear(16 * 16 * 16, 10)])),
    ]
    return CNNModel("tinycnn", blocks, input_hw=32)


def transport_overhead(smoke: bool = False,
                       out_path: Path = BENCH_JSON) -> list[str]:
    """Per-hop µs across transports × framings → BENCH_transport.json."""
    import jax

    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    n_batches = 4 if smoke else 15

    combos = COMBOS
    if smoke:
        # each process pipeline costs seconds of spawn+jit on a small
        # host; the smoke tier proves every transport end-to-end and
        # leaves the rpc framing column to the full run
        combos = [c for c in COMBOS if c[1] == "lightweight"]
        print("[smoke: lightweight framing only — run without --smoke "
              "for the full transport x framing matrix]")
    rows: list[str] = []
    results = {"model": model.name, "input_hw": 32, "batch": 2,
               "cut": 2, "n_batches": n_batches, "combos": {}}
    print("== transport overhead (per-hop, one activation transfer) ==")
    for transport, backend in combos:
        r = _one_combo(model, params, x, transport, backend, n_batches)
        results["combos"][f"{transport}/{backend}"] = r
        tag = "measured" if r["measured"] else "modeled "
        print(f"  {transport:>8}/{backend:<11} [{tag}] "
              f"hop={r['hop_us']:9.1f}us  ({r['nbytes']} B)  "
              f"latency={r['latency_ms']:7.2f}ms")
        rows.append(f"transport/{transport}_{backend},{r['hop_us']:.3f},"
                    f"lat_ms={r['latency_ms']:.3f}")
    if "socket/rpc" in results["combos"]:
        lw = results["combos"]["socket/lightweight"]["hop_us"]
        rpc = results["combos"]["socket/rpc"]["hop_us"]
        print(f"  -> measured socket framing cost: rpc/lightweight = "
              f"{rpc / max(lw, 1e-9):.2f}x")
    out_path.write_text(json.dumps(results, indent=1))
    print(f"[wrote {out_path}]")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run (< 30 s) that still writes "
                         "BENCH_transport.json")
    args = ap.parse_args()
    rows = transport_overhead(smoke=args.smoke)
    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
