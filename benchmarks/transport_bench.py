"""Transport-overhead comparison: modeled vs. measured hops.

Two views of the hop cost, both written to ``BENCH_transport.json``:

  * **sweep** — a payload-size sweep (256 B → 8 MiB) over one real hop
    per process transport (``repro.runtime.transport.measure_hop``:
    spawned sink process, credit-paced so every transfer measures true
    per-hop cost, receiver-side records).  This is where the
    shmem-vs-socket crossover lives, and the 64 KiB entry is the
    reference point for the doorbell-ring redesign (the tinycnn
    batch-2 activation is exactly 64 KiB).
  * **combos** — the same tiny model + cut run as a full 2-stage
    pipeline over every transport × framing combination (per-hop cost
    *in situ*: jit compute, stats harvest, and scheduler contention
    included), the paper's lightweight-vs-rpc backend study.

    PYTHONPATH=src python -m benchmarks.transport_bench [--smoke]
        [--sizes 256,4096,...] [--check]

``--smoke`` shrinks batch counts and the size grid (< 30 s, the
Makefile ``bench-transport`` target) and still writes the JSON.
``--check`` runs a fresh smoke measurement and *diffs it against the
committed* ``BENCH_transport.json`` instead of overwriting it, failing
on a >25 % hop_us regression (with a small absolute floor so µs-scale
noise cannot trip it) — the ``make bench-transport-check`` / ``make
fast`` regression gate.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import numpy as np

BENCH_JSON = Path("BENCH_transport.json")

COMBOS = [("emulated", "lightweight"), ("emulated", "rpc"),
          ("socket", "lightweight"), ("socket", "rpc"),
          ("shmem", "lightweight"), ("shmem", "rpc")]

SWEEP_SIZES = [256, 1024, 4096, 16384, 65536, 262144, 1 << 20,
               4 << 20, 8 << 20]
SMOKE_SIZES = [4096, 65536, 1 << 20]
# the doorbell study lives at small payloads: past a few KiB the copy
# dominates and the wakeup path stops mattering
DOORBELL_SIZES = [256, 1024, 4096]

# --check tolerances: fail only when fresh shmem is >25 % *and*
# >100 µs worse than committed *after normalizing by the same-run
# socket cost* (socket is the in-run control: ambient load on this
# shared, CPU-throttled host moves both transports together by factors
# the gate must not confuse with a code regression).  The comparison
# uses the per-size *minimum* hop cost — the intrinsic cost of the
# path, which scheduler noise can only inflate — and the absolute
# floor absorbs the tens-of-µs wakeup jitter left at small sizes.  The
# regressions this guards (pickle or an mp.Queue sneaking back onto
# the hot path) cost hundreds of µs per transfer, far above both
# tolerances.  A second, load-free invariant rides along: fresh shmem
# must beat fresh socket (median) at every swept size ≥ 1 MiB — the
# regime where the slot memcpy beats TCP's double kernel copy, checked
# within one run.  (On a *quiet* host, loopback TCP ping-pong is
# genuinely competitive below that: the old ≥ 4 KiB bound was an
# artifact of the loaded box the first reference was measured on, and
# tripped the moment the host idled.)
CHECK_REL = 1.25
CHECK_ABS_US = 100.0
CHECK_INVARIANT_MIN_BYTES = 1 << 20
# when the socket control itself reads this much slower than committed
# on every attempt, the host is starved and a wall-clock comparison
# cannot tell a code regression from scheduler starvation — skip loudly
# (shmem is *more* starvation-sensitive than its socket control: its
# credit loop needs both processes scheduled, so the threshold is low)
CHECK_MAX_LOAD = 1.5


def _one_combo(model, params, x, transport: str, backend: str,
               n_batches: int) -> dict:
    from repro.core.devices import LOOPBACK
    from repro.runtime.edge import EdgePipeline

    with EdgePipeline(model, params, 2, [LOOPBACK], backend=backend,
                      transport=transport) as pipe:
        pipe.warmup(x)
        pipe.run_one(x)                       # settle caches / first-touch
        pipe.nets[0].drain_observations()
        lats = []
        for _ in range(n_batches):
            _, lat, _ = pipe.run_one(x)
            lats.append(lat)
        recs = [r for r in pipe.nets[0].drain_observations() if r.nbytes > 0]
        return {
            "transport": transport,
            "backend": backend,
            "measured": transport != "emulated",
            # medians: lone-batch transfers on a small shared host are
            # heavy-tailed (scheduler preemption), and the tail is not
            # what the framing comparison is about
            "hop_us": float(np.median([r.elapsed_s for r in recs]) * 1e6),
            "hop_us_min": float(min(r.elapsed_s for r in recs) * 1e6),
            "nbytes": int(recs[0].nbytes),
            "latency_ms": float(np.median(lats) * 1e3),
        }


def _tiny_model():
    """A 5-block CNN that jit-compiles in a blink — the hop cost is the
    thing under test, not the compute."""
    from repro.models.cnn.layers import (Conv2D, Flatten, Linear, Pool,
                                         ReLU, Sequential)
    from repro.models.cnn.zoo import CNNModel
    blocks = [
        ("conv0", Sequential([Conv2D(3, 8, 3, 1, 1), ReLU()])),
        ("conv1", Sequential([Conv2D(8, 8, 3, 1, 1), ReLU()])),
        ("pool", Pool("max", 2, 2)),
        ("conv2", Sequential([Conv2D(8, 16, 3, 1, 1), ReLU()])),
        ("head", Sequential([Flatten(), Linear(16 * 16 * 16, 10)])),
    ]
    return CNNModel("tinycnn", blocks, input_hw=32)


def size_sweep(sizes: list[int], n_per_size: int) -> dict:
    """Per-size hop cost over one real hop per process transport →
    the sweep block of BENCH_transport.json (incl. the crossover)."""
    from repro.runtime.transport import measure_hop

    per: dict[str, dict[str, float]] = {}
    for transport in ("socket", "shmem"):
        out = measure_hop(transport, sizes, n_per_size=n_per_size)
        per[transport + "_us"] = {
            str(n): float(np.median(v) * 1e6) for n, v in sorted(out.items())}
        per[transport + "_us_min"] = {
            str(n): float(min(v) * 1e6) for n, v in sorted(out.items())}
    crossover = None
    for n in sorted(sizes):
        if per["shmem_us"][str(n)] < per["socket_us"][str(n)]:
            crossover = n
            break
    return {
        "sizes": sorted(sizes),
        "n_per_size": n_per_size,
        "socket_us": per["socket_us"],
        "shmem_us": per["shmem_us"],
        "socket_us_min": per["socket_us_min"],
        "shmem_us_min": per["shmem_us_min"],
        # smallest swept payload where shmem wins (None = never)
        "crossover_bytes": crossover,
    }


def _ring_burst_ns(flavor: str, n: int = 50_000) -> float:
    """ns per ``ring()`` while the waiter is busy (rings coalesce) —
    the replicated fan-in hot path, where r producers ring one ingress
    doorbell.  A full socketpair buffer makes every further ring pay a
    raised-and-caught ``BlockingIOError``; the eventfd counter just
    adds.  In-process and syscall-bound, so unlike the parked-hop
    numbers this is scheduler-noise-free."""
    import time

    from repro.runtime.transport import _bell_pair

    ring, wait = _bell_pair(flavor)
    try:
        for _ in range(500):                  # fill the buffer / warm up
            ring.ring()
        t0 = time.perf_counter()
        for _ in range(n):
            ring.ring()
        return (time.perf_counter() - t0) / n * 1e9
    finally:
        ring.close()
        wait.close()


def doorbell_sweep(sizes: list[int], n_per_size: int) -> dict:
    """Doorbell comparison: eventfd (one fd, kernel counter) vs the
    portable socketpair fallback.  Two views: the burst-ring microbench
    (deterministic — where the eventfd win lives) and the parked hop
    cost (``spin_us=0``: every transfer waits on the bell; on a small
    shared host this is wakeup-scheduling-bound, so flavors are run
    interleaved and pooled to keep the comparison fair)."""
    import os

    from repro.runtime.transport import measure_hop

    out: dict = {"sizes": sorted(sizes), "n_per_size": n_per_size,
                 "eventfd_available": hasattr(os, "eventfd")}
    flavors = ["socketpair"] + (["eventfd"] if out["eventfd_available"]
                                else [])
    out["ring_burst_ns"] = {f: float(_ring_burst_ns(f)) for f in flavors}
    if "eventfd" in flavors:
        out["ring_win"] = (out["ring_burst_ns"]["socketpair"]
                           / max(out["ring_burst_ns"]["eventfd"], 1e-9))
    pooled: dict[str, dict[int, list[float]]] = {f: {} for f in flavors}
    for _rep in range(2):
        for bell in flavors:
            res = measure_hop("shmem", sizes,
                              n_per_size=max(n_per_size // 2, 4),
                              spin_us=0.0, bell=bell)
            for n, v in res.items():
                pooled[bell].setdefault(n, []).extend(v)
    for bell in flavors:
        out[bell + "_us"] = {
            str(n): float(np.median(v) * 1e6)
            for n, v in sorted(pooled[bell].items())}
        out[bell + "_us_min"] = {
            str(n): float(min(v) * 1e6)
            for n, v in sorted(pooled[bell].items())}
    return out


def transport_overhead(smoke: bool = False,
                       out_path: Path = BENCH_JSON,
                       sizes: list[int] | None = None) -> list[str]:
    """Per-hop µs across transports × framings + the payload-size sweep
    → BENCH_transport.json.  Returns harness CSV rows."""
    rows, _ = _measure(smoke=smoke, out_path=out_path, sizes=sizes,
                       write=True)
    return rows


def _measure(smoke: bool, out_path: Path = BENCH_JSON,
             sizes: list[int] | None = None,
             write: bool = True) -> tuple[list[str], dict]:
    import jax

    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    n_batches = 4 if smoke else 15
    if sizes is None:
        sizes = SMOKE_SIZES if smoke else SWEEP_SIZES

    combos = COMBOS
    if smoke:
        # each process pipeline costs seconds of spawn+jit on a small
        # host; the smoke tier proves every transport end-to-end and
        # leaves the rpc framing column to the full run
        combos = [c for c in COMBOS if c[1] == "lightweight"]
        print("[smoke: lightweight framing only — run without --smoke "
              "for the full transport x framing matrix]")
    rows: list[str] = []
    results = {"model": model.name, "input_hw": 32, "batch": 2,
               "cut": 2, "n_batches": n_batches, "combos": {}}

    print("== hop cost vs payload size (one real hop, credit-paced) ==")
    sweep = size_sweep(sizes, n_per_size=8 if smoke else 30)
    results["sweep"] = sweep
    print(f"  {'bytes':>9}  {'socket us':>10}  {'shmem us':>10}")
    for n in sweep["sizes"]:
        s, m = sweep["socket_us"][str(n)], sweep["shmem_us"][str(n)]
        win = "shmem" if m < s else "socket"
        print(f"  {n:>9}  {s:>10.1f}  {m:>10.1f}  <- {win}")
        rows.append(f"transport/sweep_{n}B,{m:.3f},socket_us={s:.3f}")
    print(f"  -> shmem wins from {sweep['crossover_bytes']} B up")
    if "65536" in sweep["shmem_us"]:
        results["reference_64k_shmem_us"] = sweep["shmem_us"]["65536"]

    print("== doorbell: eventfd vs socketpair (shmem, small payloads) ==")
    bells = doorbell_sweep(DOORBELL_SIZES, n_per_size=8 if smoke else 30)
    results["doorbell"] = bells
    rb = bells["ring_burst_ns"]
    if "ring_win" in bells:
        print(f"  burst ring (coalesced): socketpair {rb['socketpair']:.0f}ns"
              f"  eventfd {rb['eventfd']:.0f}ns  "
              f"({bells['ring_win']:.2f}x cheaper)")
        rows.append(f"transport/doorbell_ring_ns,{rb['eventfd']:.1f},"
                    f"socketpair_ns={rb['socketpair']:.1f}")
    else:
        print(f"  burst ring: socketpair {rb['socketpair']:.0f}ns "
              f"(no eventfd here)")
    for n in bells["sizes"]:
        sp = bells["socketpair_us"][str(n)]
        if "eventfd_us" in bells:
            ev = bells["eventfd_us"][str(n)]
            print(f"  {n:>9}  parked hop: socketpair {sp:>8.1f}us  "
                  f"eventfd {ev:>8.1f}us")
            rows.append(f"transport/doorbell_{n}B,{ev:.3f},"
                        f"socketpair_us={sp:.3f}")
        else:
            print(f"  {n:>9}  parked hop: socketpair {sp:>8.1f}us")
            rows.append(f"transport/doorbell_{n}B,{sp:.3f},no_eventfd")

    print("== transport overhead (per-hop, one activation transfer, "
          "in-pipeline) ==")
    for transport, backend in combos:
        r = _one_combo(model, params, x, transport, backend, n_batches)
        results["combos"][f"{transport}/{backend}"] = r
        tag = "measured" if r["measured"] else "modeled "
        print(f"  {transport:>8}/{backend:<11} [{tag}] "
              f"hop={r['hop_us']:9.1f}us  ({r['nbytes']} B)  "
              f"latency={r['latency_ms']:7.2f}ms")
        rows.append(f"transport/{transport}_{backend},{r['hop_us']:.3f},"
                    f"lat_ms={r['latency_ms']:.3f}")
    if "socket/rpc" in results["combos"]:
        lw = results["combos"]["socket/lightweight"]["hop_us"]
        rpc = results["combos"]["socket/rpc"]["hop_us"]
        print(f"  -> measured socket framing cost: rpc/lightweight = "
              f"{rpc / max(lw, 1e-9):.2f}x")
    if write:
        out_path.write_text(json.dumps(results, indent=1))
        print(f"[wrote {out_path}]")
    return rows, results


def _check_one(fresh: dict, ref: dict) -> list[str]:
    """Regressions of fresh vs committed shmem hop cost (socket-
    normalized), plus the shmem-beats-socket invariant."""
    bad: list[str] = []
    f_sw, r_sw = fresh.get("sweep", {}), ref.get("sweep", {})
    sizes = sorted(set(r_sw.get("shmem_us_min", {}))
                   & set(f_sw.get("shmem_us_min", {}))
                   & set(r_sw.get("socket_us_min", {}))
                   & set(f_sw.get("socket_us_min", {})), key=int)
    for n in sizes:
        # load normalization may only *excuse* a loaded host (scale > 1);
        # a lucky fresh socket sample must not tighten the bar below the
        # committed reference
        scale = max(1.0, f_sw["socket_us_min"][n]
                    / max(r_sw["socket_us_min"][n], 1e-9))
        allowed = r_sw["shmem_us_min"][n] * scale
        new_us = f_sw["shmem_us_min"][n]
        if new_us > allowed * CHECK_REL and new_us > allowed + CHECK_ABS_US:
            bad.append(
                f"sweep/shmem@{n}B: min {new_us:.1f}us vs committed "
                f"{r_sw['shmem_us_min'][n]:.1f}us x{scale:.2f} load "
                f"(socket control) = {allowed:.1f}us allowed "
                f"(>{(CHECK_REL - 1) * 100:.0f}%)")
    for n in sizes:
        if int(n) < CHECK_INVARIANT_MIN_BYTES:
            continue
        med_m, med_s = f_sw["shmem_us"][n], f_sw["socket_us"][n]
        if med_m >= med_s:
            bad.append(f"sweep/invariant@{n}B: shmem median "
                       f"{med_m:.1f}us >= socket median {med_s:.1f}us")
    return bad


def check(ref_path: Path = BENCH_JSON) -> int:
    """Fresh smoke measurement vs the committed reference → exit code.
    Retries once before failing: a single unlucky scheduling window on
    a busy host is not a regression."""
    if not ref_path.exists():
        print(f"[check] no committed {ref_path}; run the bench first")
        return 2
    ref = json.loads(ref_path.read_text())
    if not ref.get("sweep", {}).get("shmem_us_min"):
        # a reference without the sweep block would make every
        # comparison vacuous — that is a broken baseline, not a pass
        print(f"[check] committed {ref_path} has no sweep block; "
              f"regenerate it with `make bench-transport` first")
        return 2
    loads: list[float] = []
    for attempt in (1, 2, 3):
        # the gate reads only the sweep — skip the (slow, jit-heavy)
        # combo pipelines entirely
        # as many samples as the committed reference: the comparison is
        # min-vs-min, and a thinner sample systematically loses it
        fresh = {"sweep": size_sweep(SMOKE_SIZES, n_per_size=30)}
        if "65536" in fresh["sweep"]["shmem_us"]:
            print(f"[check] fresh 64KiB: shmem "
                  f"{fresh['sweep']['shmem_us']['65536']:.1f}us / socket "
                  f"{fresh['sweep']['socket_us']['65536']:.1f}us")
        bad = _check_one(fresh, ref)
        if not bad:
            print(f"[check] OK — no hop_us regression vs {ref_path}")
            return 0
        ref_min = ref["sweep"]["socket_us_min"]
        new_min = fresh["sweep"]["socket_us_min"]
        shared = set(ref_min) & set(new_min)
        loads.append(float(np.median(
            [new_min[n] / max(ref_min[n], 1e-9) for n in shared])) if shared
            else 1.0)
        print(f"[check] attempt {attempt}: {len(bad)} regression(s) "
              f"(socket control at x{loads[-1]:.2f} committed)")
        for b in bad:
            print(f"    {b}")
    if min(loads) > CHECK_MAX_LOAD:
        print(f"[check] SKIPPED — socket control ran >= x{min(loads):.1f} "
              f"slower than committed on every attempt: the host is "
              f"starved, and wall-clock here cannot tell a regression "
              f"from scheduler starvation.  Re-run on a quieter host.")
        return 0
    print(f"[check] FAIL — hop_us regressed vs committed {ref_path}")
    return 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run (< 30 s) that still writes "
                         "BENCH_transport.json")
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated payload sizes in bytes for the "
                         "sweep (default: 256B..8MiB)")
    ap.add_argument("--check", action="store_true",
                    help="measure fresh and diff against the committed "
                         "BENCH_transport.json (no overwrite); exit 1 on "
                         ">25%% hop_us regression")
    args = ap.parse_args()
    if args.check:
        sys.exit(check())
    sizes = ([int(s) for s in args.sizes.split(",")] if args.sizes else None)
    rows = transport_overhead(smoke=args.smoke, sizes=sizes)
    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
