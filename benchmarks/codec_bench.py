"""Per-hop wire codec benchmark: bytes-on-wire, hop cost, accuracy.

Four views of the codec layer, all written to ``BENCH_codec.json``:

  * **sweep** — payload-size sweep per process transport × codec over
    one real hop (``measure_hop`` with per-frame packing): wire bytes,
    compression ratio, and receiver-measured hop µs.  Unpaced, so this
    is the *packing overhead* view — on a fast local link the lossy
    codecs pay encode/decode CPU for bytes the link doesn't miss.
  * **wan** — the same hop paced by the paper's duress WAN
    (``pace_link=DURESS``: the sender charges each frame the link's
    transfer time *for the packed size*), the bytes-dominated regime
    where the codec's 4× wire cut becomes a ~40 % hop-time cut.  The
    acceptance gate lives here: int8 must compress fp32 ≥64 KiB by
    ≥3.5× on the wire AND strictly beat ``none`` in measured hop time.
  * **accuracy** — ``calibrate_codecs`` on the tiny CNN: per-codec
    worst/median top-1 agreement and worst output perturbation across
    every cut (the fourth Pareto axis the solver prunes on).
  * **wan_dip** — end-to-end study: a streaming ``Session`` with an
    ``AdaptiveController`` whose splitter searches partition × codec
    (``codec_choices``) under the ``congestion_spike`` trace — the
    controller coarsens the wire codec when the spike hits and the
    timeline records codecs, latency, and the charged switch cost.

    PYTHONPATH=src python -m benchmarks.codec_bench [--smoke] [--check]
        [--sizes 4096,65536,...]

``--smoke`` shrinks the grids (< 60 s, the Makefile ``bench-codec``
target) and still writes the JSON.  ``--check`` re-measures just the
gate quantities (64 KiB sweep + paced WAN hop) and fails unless the
acceptance invariants hold in the fresh run *and* the committed JSON —
the ``make bench-codec-check`` / ``make fast`` regression gate.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

BENCH_JSON = Path("BENCH_codec.json")

CODEC_NAMES = ["none", "int8", "fp8", "topk"]
SWEEP_SIZES = [4096, 65536, 262144, 1 << 20]
SMOKE_SIZES = [65536]
WAN_SIZE = 65536                 # the tinycnn batch-2 activation

# acceptance gate: int8 wire reduction for fp32 >= 64 KiB, and the
# paced-WAN hop must get strictly faster than uncoded
GATE_MIN_RATIO = 3.5


def _tiny_model():
    from repro.models.cnn.layers import (Conv2D, Flatten, Linear, Pool,
                                         ReLU, Sequential)
    from repro.models.cnn.zoo import CNNModel
    blocks = [
        ("conv0", Sequential([Conv2D(3, 8, 3, 1, 1), ReLU()])),
        ("conv1", Sequential([Conv2D(8, 8, 3, 1, 1), ReLU()])),
        ("pool", Pool("max", 2, 2)),
        ("conv2", Sequential([Conv2D(8, 16, 3, 1, 1), ReLU()])),
        ("head", Sequential([Flatten(), Linear(16 * 16 * 16, 10)])),
    ]
    return CNNModel("tinycnn", blocks, input_hw=32)


def _hop_stats(recs) -> dict:
    """One measure_hop size bucket (full records) → summary dict."""
    return {
        "hop_us": float(np.median([r.elapsed_s for r in recs]) * 1e6),
        "hop_us_min": float(min(r.elapsed_s for r in recs) * 1e6),
        "raw_bytes": int(recs[0].raw_bytes),
        "wire_bytes": int(recs[0].nbytes),
        "ratio": float(recs[0].raw_bytes / max(recs[0].nbytes, 1)),
    }


def codec_sweep(sizes: list[int], n_per_size: int,
                transports=("socket", "shmem")) -> dict:
    """Unpaced hop cost + wire bytes per transport × codec × size."""
    from repro.runtime.transport import measure_hop
    out: dict = {"sizes": sorted(sizes), "n_per_size": n_per_size}
    for transport in transports:
        per: dict[str, dict] = {}
        for codec in CODEC_NAMES:
            buckets = measure_hop(transport, sizes, n_per_size=n_per_size,
                                  codec=codec, full=True)
            per[codec] = {str(n): _hop_stats(v)
                          for n, v in sorted(buckets.items())}
        out[transport] = per
    return out


def wan_hop_block(n_per_size: int, size: int = WAN_SIZE) -> dict:
    """Duress-WAN-paced socket hop per codec → the acceptance gate.

    ``pace_link=DURESS`` charges every frame the WAN's transfer time for
    its *packed* size, so wire-byte reduction shows up directly in the
    receiver-measured hop time (200 ms RTT / 5 Mbit: 64 KiB costs
    ~205 ms uncoded, ~126 ms packed 4×).  Warmup/depth are trimmed:
    every paced transfer sleeps the WAN time, and the sleep — not page
    faults — dominates what is measured."""
    from repro.core import devices as D
    from repro.core.codecs import codec_wire_bytes
    from repro.runtime.transport import measure_hop
    link = D.DURESS
    codecs: dict[str, dict] = {}
    for codec in CODEC_NAMES:
        buckets = measure_hop("socket", [size], n_per_size=n_per_size,
                              warmup=2, depth=2, codec=codec,
                              pace_link=link, full=True, timeout_s=120.0)
        st = _hop_stats(buckets[size])
        st["modeled_us"] = float(
            link.transfer_time(codec_wire_bytes(codec, size)) * 1e6)
        codecs[codec] = st
    gate = {
        "int8_ratio": codecs["int8"]["ratio"],
        "int8_hop_us": codecs["int8"]["hop_us"],
        "none_hop_us": codecs["none"]["hop_us"],
        "int8_speedup": codecs["none"]["hop_us"] / codecs["int8"]["hop_us"],
        "pass": (codecs["int8"]["ratio"] >= GATE_MIN_RATIO
                 and codecs["int8"]["hop_us"] < codecs["none"]["hop_us"]),
    }
    return {"link": link.name, "transport": "socket", "size": size,
            "n_per_size": n_per_size, "codecs": codecs, "gate": gate}


def accuracy_block() -> dict:
    """Measured per-cut degradation on the tiny CNN (held batch)."""
    import jax
    from repro.core.codecs import calibrate_codecs
    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(7), (8, 32, 32, 3))
    cal = calibrate_codecs(model, params, x)
    out: dict = {"model": model.name, "batch": int(x.shape[0]),
                 "per_cut": {}, "summary": {}}
    names = [c for c in CODEC_NAMES if c != "none"]
    for (cut, name), acc in sorted(cal.table.items()):
        out["per_cut"].setdefault(str(cut), {})[name] = {
            "top1_agreement": acc.top1_agreement,
            "max_abs_err": acc.max_abs_err,
        }
    for name in names:
        t1 = [a.top1_agreement for (c, n), a in cal.table.items()
              if n == name]
        err = [a.max_abs_err for (c, n), a in cal.table.items()
               if n == name]
        out["summary"][name] = {
            "top1_min": float(min(t1)),
            "top1_median": float(np.median(t1)),
            "max_abs_err_worst": float(max(err)),
        }
    return out


def wan_dip(n_batches: int, period_s: float = 0.1) -> dict:
    """End-to-end: adaptive codec coarsening through congestion_spike.

    The splitter searches partition × codec with an accuracy floor; as
    the hop-0 trace degrades toward the duress WAN the controller ships
    a RECONFIG that coarsens the wire codec (charged like a migration)."""
    from dataclasses import replace

    import jax
    from repro.core import scenarios
    from repro.core.autosplit import AdaptiveSplitter
    from repro.runtime.edge import EdgePipeline
    from repro.runtime.session import AdaptiveController

    m = _tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    scen = scenarios.get("pi_pi_gpu_congestion_spike")
    graph = m.block_graph(input_hw=32)
    splitter = AdaptiveSplitter(graph, scen, batch=x.shape[0],
                                policy="latency", include_io=False,
                                hysteresis=0.10,
                                codec_choices=("none", "int8", "topk"),
                                accuracy_floor=0.95)
    # deploy *uncoded* (codec search pinned off for the initial solve):
    # on the healthy LAN the packed codecs buy too little latency to
    # clear the 10 % hysteresis, so the stream starts at full fidelity
    # and the spike is what drives the coarsening
    init = replace(splitter, codec_choices=None).solve()
    splitter.current = init
    ctrl = AdaptiveController(splitter, check_every=2, probe=False)

    with EdgePipeline(m, params, init.partition, scen,
                      codec=init.codecs or None) as pipe:
        pipe.warmup(x)
        pipe.reset_clock()
        with pipe.session(ctrl, inflight=2, policy="drop", window=4) as s:
            for _ in range(n_batches):
                s.submit(x)
                time.sleep(period_s)   # let the trace clock advance
            for _ in s.results():
                pass
        recs = list(s.records)
        migrations = len(pipe.migrations)

    # results complete slightly out of submit order under inflight>1;
    # order the trail by pipeline clock, not completion
    trail, last = [], None
    for r in sorted(recs, key=lambda r: r.t_s):
        if r.codecs != last:
            trail.append({"t_s": round(r.t_s, 3), "batch": r.batch_idx,
                          "cuts": list(r.cuts), "codecs": list(r.codecs)})
            last = r.codecs
    charged = [r for r in recs if r.migration_cost_s > 0]
    coarsened = any(any(c != "none" for c in e["codecs"]) for e in trail[1:])
    refined = bool(trail) and all(c == "none" for c in trail[-1]["codecs"]) \
        and len(trail) > 1
    return {
        "scenario": scen.name,
        "n_batches": n_batches,
        "init_cuts": list(init.partition),
        "init_codecs": list(init.codecs or ()),
        "codec_trail": trail,
        "migrations": migrations,
        "switch_costs_s": [round(r.migration_cost_s, 4) for r in charged],
        "coarsened_during_spike": coarsened,
        "refined_after_spike": refined,
        "final_latency_ms": float(np.median(
            [r.latency_s for r in recs[-4:]]) * 1e3) if recs else None,
    }


def codec_overhead(smoke: bool = False, out_path: Path = BENCH_JSON,
                   sizes: list[int] | None = None) -> list[str]:
    """Full measurement → BENCH_codec.json.  Returns harness CSV rows."""
    rows, _ = _measure(smoke=smoke, out_path=out_path, sizes=sizes,
                       write=True)
    return rows


def _measure(smoke: bool, out_path: Path = BENCH_JSON,
             sizes: list[int] | None = None,
             write: bool = True) -> tuple[list[str], dict]:
    if sizes is None:
        sizes = SMOKE_SIZES if smoke else SWEEP_SIZES
    rows: list[str] = []
    results: dict = {"wan_size": WAN_SIZE, "gate_min_ratio": GATE_MIN_RATIO}

    print("== wire bytes + hop cost per transport x codec (unpaced) ==")
    sweep = codec_sweep(sizes, n_per_size=6 if smoke else 20)
    results["sweep"] = sweep
    for transport in ("socket", "shmem"):
        for n in sweep["sizes"]:
            line = f"  {transport:>6} {n:>8}B "
            for codec in CODEC_NAMES:
                st = sweep[transport][codec][str(n)]
                line += f" {codec}={st['hop_us']:8.1f}us/{st['ratio']:4.2f}x"
            print(line)
    st64 = sweep["socket"]["int8"].get(str(WAN_SIZE))
    if st64:
        rows.append(f"codec/sweep_socket_int8_{WAN_SIZE}B,"
                    f"{st64['hop_us']:.3f},ratio={st64['ratio']:.2f}")

    print("== duress-WAN paced hop (socket, 64 KiB fp32) — the gate ==")
    wan = wan_hop_block(n_per_size=4 if smoke else 8)
    results["wan"] = wan
    for codec in CODEC_NAMES:
        st = wan["codecs"][codec]
        print(f"  {codec:>5}: hop={st['hop_us'] / 1e3:7.1f}ms "
              f"(model {st['modeled_us'] / 1e3:6.1f}ms)  "
              f"wire={st['wire_bytes']:>7}B  {st['ratio']:4.2f}x")
        rows.append(f"codec/wan_{codec},{st['hop_us']:.3f},"
                    f"ratio={st['ratio']:.2f}")
    g = wan["gate"]
    print(f"  -> gate: int8 {g['int8_ratio']:.2f}x wire, "
          f"{g['int8_speedup']:.2f}x faster than none "
          f"[{'PASS' if g['pass'] else 'FAIL'}]")

    print("== measured accuracy per codec (tinycnn, all cuts) ==")
    acc = accuracy_block()
    results["accuracy"] = acc
    for name, s in acc["summary"].items():
        print(f"  {name:>5}: top1 agreement min={s['top1_min']:.3f} "
              f"median={s['top1_median']:.3f}  "
              f"worst |err|={s['max_abs_err_worst']:.4f}")
        rows.append(f"codec/accuracy_{name},0.0,"
                    f"top1_min={s['top1_min']:.3f}")

    print("== end-to-end WAN dip: adaptive codec coarsening ==")
    dip = wan_dip(n_batches=45 if smoke else 70)
    results["wan_dip"] = dip
    for e in dip["codec_trail"]:
        print(f"  t={e['t_s']:5.2f}s batch {e['batch']:>3} "
              f"cuts={e['cuts']} codecs={e['codecs']}")
    print(f"  -> coarsened during spike: {dip['coarsened_during_spike']}  "
          f"refined after: {dip['refined_after_spike']}  "
          f"switch costs: {dip['switch_costs_s']}")
    rows.append(f"codec/wan_dip,0.0,"
                f"coarsened={int(dip['coarsened_during_spike'])};"
                f"switches={len(dip['codec_trail']) - 1}")

    if write:
        out_path.write_text(json.dumps(results, indent=1))
        print(f"[wrote {out_path}]")
    return rows, results


def check(ref_path: Path = BENCH_JSON) -> int:
    """Re-measure just the gate quantities and verify the acceptance
    invariants live + in the committed JSON → exit code.

    The paced-WAN comparison is dominated by deterministic pace sleeps
    (205 ms uncoded vs 126 ms int8 at 64 KiB — a ~79 ms gap scheduler
    noise cannot close), so unlike the raw transport gate no load
    normalization is needed; one retry absorbs a pathological window."""
    if not ref_path.exists():
        print(f"[check] no committed {ref_path}; run the bench first")
        return 2
    ref = json.loads(ref_path.read_text())
    rgate = ref.get("wan", {}).get("gate", {})
    bad: list[str] = []
    if not rgate.get("pass"):
        bad.append(f"committed {ref_path} gate is not passing; "
                   f"regenerate with `make bench-codec`")
    for attempt in (1, 2):
        fresh_bad: list[str] = []
        sweep = codec_sweep([WAN_SIZE], n_per_size=4,
                            transports=("socket",))
        st = sweep["socket"]["int8"][str(WAN_SIZE)]
        if st["ratio"] < GATE_MIN_RATIO:
            fresh_bad.append(f"int8 wire ratio {st['ratio']:.2f}x < "
                             f"{GATE_MIN_RATIO}x at {WAN_SIZE}B")
        wan = wan_hop_block(n_per_size=3)
        g = wan["gate"]
        if not g["pass"]:
            fresh_bad.append(
                f"paced-WAN gate failed: int8 {g['int8_hop_us'] / 1e3:.1f}ms"
                f" vs none {g['none_hop_us'] / 1e3:.1f}ms "
                f"(ratio {g['int8_ratio']:.2f}x)")
        if not fresh_bad:
            break
        print(f"[check] attempt {attempt} failed: {'; '.join(fresh_bad)}")
    bad += fresh_bad
    if bad:
        print("[check] FAIL")
        for b in bad:
            print(f"  - {b}")
        return 1
    print(f"[check] OK: int8 {st['ratio']:.2f}x wire, paced-WAN "
          f"{g['int8_speedup']:.2f}x faster than none "
          f"(committed gate pass)")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--sizes", default="",
                    help="comma-separated payload bytes for the sweep")
    args = ap.parse_args()
    if args.check:
        sys.exit(check())
    sizes = [int(s) for s in args.sizes.split(",") if s] or None
    _measure(smoke=args.smoke, sizes=sizes, write=True)


if __name__ == "__main__":
    main()
