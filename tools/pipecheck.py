#!/usr/bin/env python
"""PipeCheck CLI — static protocol invariants over src/.

Usage:
    python tools/pipecheck.py                 # grouped human report
    python tools/pipecheck.py --fix-report    # file:line: RULE ... lines
    python tools/pipecheck.py --rules R1,R4   # subset of rules
    python tools/pipecheck.py --root PATH     # check another checkout

Exit status is 1 when any finding is reported, 0 on a clean tree.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_REPO / "src"))

from repro.analysis.pipecheck import RULE_DOCS, RULES, scan_tree  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=str(_REPO),
                    help="repo root to check (default: this checkout)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rules, e.g. R1,R4")
    ap.add_argument("--fix-report", action="store_true",
                    help="emit one clickable `file:line: RULE message` "
                         "line per finding")
    args = ap.parse_args(argv)

    rules = None
    if args.rules:
        rules = tuple(r.strip().upper() for r in args.rules.split(",") if r)
        unknown = sorted(set(rules) - set(RULES))
        if unknown:
            ap.error(f"unknown rules {unknown}; known: {', '.join(RULES)}")

    t0 = time.perf_counter()
    findings = scan_tree(args.root, rules)
    dt_ms = (time.perf_counter() - t0) * 1e3

    if args.fix_report:
        for f in findings:
            print(f.render())
    else:
        if not findings:
            checked = ", ".join(rules or RULES)
            print(f"pipecheck: clean ({checked}) in {dt_ms:.0f} ms")
        for rule in sorted({f.rule for f in findings}):
            doc = RULE_DOCS.get(rule, "")
            group = [f for f in findings if f.rule == rule]
            print(f"\n{rule} — {doc}  [{len(group)} finding(s)]")
            for f in group:
                print(f"  {f.path}:{f.line}: {f.message}")
        if findings:
            print(f"\npipecheck: {len(findings)} finding(s) in {dt_ms:.0f} ms")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
